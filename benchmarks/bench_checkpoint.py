"""Checkpoint tier: a 3x-over-budget working set vs naive home re-staging.

The scenario (ISSUE 4 acceptance): a pilot whose volatile budgets
(device+host) hold only ~1/3 of an iterated KMeans working set, with the
DataUnit homed on a SLOW original file store (simulated remote/parallel
filesystem).  Two runs:

  restage — no checkpoint tier: replication of the overflow is refused
      (nothing colder than the tiny host tier), so every iteration
      re-reads the overflow partitions from the slow home store;
  tiered  — the same budgets plus a node-local checkpoint tier (fast
      flash profile): the overflow spills to the durable store once and
      iterations restore it lazily from local disk, re-promoting through
      the same hierarchy.

Both runs must agree numerically; the tiered run completing AND beating
the restage baseline is the CI gate (BENCH_pr4.json:
bench_checkpoint.tiered {completed, speedup_vs_restage}).
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, record

ITERS = 4
DEPTH = 4
K = 8


def _profile(name, part_bytes, read_ms, write_ms):
    from repro.core.memory import TierProfile
    return TierProfile(name, simulate=True, latency=2e-4,
                       read_bw=part_bytes / (read_ms * 1e-3),
                       write_bw=part_bytes / (write_ms * 1e-3))


def _run(pts: np.ndarray, parts: int, workdir: Path, with_checkpoint: bool):
    from repro.core import (CheckpointBackend, ComputeDataManager, DataUnit,
                            PilotComputeDescription, PilotComputeService,
                            PilotDataService, TierManager, kmeans,
                            make_backend)
    from repro.core.memory import FileBackend

    part_bytes = pts.nbytes // parts
    # volatile budgets hold ~1/3 of the working set
    device_budget = (parts // 3) * part_bytes + part_bytes // 2
    host_budget = part_bytes // 2
    backends = {"host": make_backend("host"),
                "device": make_backend("device")}
    if with_checkpoint:
        # node-local flash: ~20x faster reads than the remote home store
        backends["checkpoint"] = CheckpointBackend(
            workdir / "ckpt", _profile("bench_local_flash", part_bytes,
                                       read_ms=1.2, write_ms=0.4))
    svc = PilotComputeService()
    pds = PilotDataService()
    if with_checkpoint:
        pds.attach_checkpoint_store(backends["checkpoint"])
    manager = ComputeDataManager(svc)
    try:
        pilot = svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", stager_workers=DEPTH))
        pilot.attach_tier_manager(TierManager(
            backends, {"device": device_budget, "host": host_budget},
            promote_threshold=0, max_workers=DEPTH))
        pds.register_pilot(pilot)
        # home placement: the slow original file store every miss re-reads
        du = pds.register(DataUnit.from_array(
            "ck-bench", pts, parts,
            {"file": FileBackend(workdir / "home",
                                 _profile("bench_remote_store", part_bytes,
                                          read_ms=25.0, write_ms=2.0))},
            tier="file"))
        t0 = time.perf_counter()
        r = kmeans(du, k=K, iters=ITERS, manager=manager,
                   prefetch_depth=DEPTH)
        wall = time.perf_counter() - t0
        pilot.tier_manager.drain(timeout=60)
        tm = pilot.tier_manager
        return wall, float(r.sse_history[-1]), {
            "bytes_demoted": tm.counters["bytes_demoted"],
            "bytes_promoted": tm.counters["bytes_promoted"],
            "spilled_parts": len(tm.resident_keys("checkpoint"))
            if with_checkpoint else 0,
            "home_pulls": pds.counters["pulls"]}
    finally:
        pds.close()
        svc.cancel_all()


def run(quick: bool = False) -> float:
    from repro.core import DataUnit, kmeans, make_backend, make_blobs

    n, parts = (12_000, 12) if quick else (36_000, 12)
    pts, _ = make_blobs(n, K, d=16, seed=0)

    # warm the jit cache so neither run pays compile inside the timer
    warm = DataUnit.from_array(
        "warm-ck", pts[: n // parts], 1,
        {"host": make_backend("host"), "device": make_backend("device")},
        tier="device")
    kmeans(warm, k=K, iters=1, seed=0)

    root = Path(tempfile.mkdtemp(prefix="bench_checkpoint_"))
    try:
        wall_naive, sse_naive, stats_naive = _run(
            pts, parts, root / "restage", with_checkpoint=False)
        wall_ck, sse_ck, stats_ck = _run(
            pts, parts, root / "tiered", with_checkpoint=True)
    finally:
        shutil.rmtree(root, ignore_errors=True)

    np.testing.assert_allclose(sse_ck, sse_naive, rtol=1e-3)
    speedup = wall_naive / max(wall_ck, 1e-9)
    emit("bench_checkpoint.restage[sim]", wall_naive,
         f"sse={sse_naive:.3e} home_pulls={stats_naive['home_pulls']}")
    record("bench_checkpoint.restage", seconds=wall_naive, **stats_naive)
    emit("bench_checkpoint.tiered[sim]", wall_ck,
         f"speedup_vs_restage={speedup:.2f}x "
         f"spilled={stats_ck['spilled_parts']}")
    record("bench_checkpoint.tiered", seconds=wall_ck, completed=True,
           speedup_vs_restage=speedup, over_budget_factor=3, **stats_ck)
    if speedup < 1.0:
        emit("bench_checkpoint.WARNING", 0.0,
             f"checkpoint tier {speedup:.2f}x — slower than re-staging")
    return speedup


if __name__ == "__main__":
    from benchmarks import common
    print("name,us_per_call,derived")
    run()
    common.write_json("BENCH_pr4.json", meta={"mode": "standalone"})
