"""Task-engine throughput: batched submit_tasks vs per-CU submission.

The PR 6 tentpole gate.  Per-CU submission pays description + Future +
uuid + a manager lock-and-score pass + a queue hop PER TASK — tens of
microseconds each, capping the whole scheduling plane in the 10^4/s
range.  The raptor-style engine amortizes all of it over a batch: ONE
policy pass for the batch, slotted tasks, chunked dispatch into resident
worker pools.  The gate (enforced here under ``--quick`` and again by
``run.py``):

  * ``bench_throughput.batched`` sustains >= 10^5 tiny tasks/s on the
    in-process backend, and
  * >= 20x the measured per-CU submission rate.

A second record drives the batch across 4 pilots (the select_batch
round-robin path + sharded stats locks) to keep the multi-pilot plane
honest — it shares the 10^5/s floor.
"""
from __future__ import annotations

import sys
import time

from benchmarks import common
from repro.core import PilotSession

# the peak-rate run uses ONE worker per pilot: tiny pure-Python tasks
# serialize on the GIL, so a second worker only adds contention (real
# workloads releasing the GIL — jax, numpy, IO — scale with task_workers)
N_SINGLE = 2_000
N_BATCH_QUICK = 100_000
N_BATCH_FULL = 300_000

THROUGHPUT_MIN_TASKS_PER_S = 1e5
THROUGHPUT_MIN_SPEEDUP = 20.0


def _tiny() -> int:
    return 1


def _single_rate(s: PilotSession, n: int) -> float:
    """Per-CU submission baseline: n tiny CUs through manager.submit."""
    t0 = time.perf_counter()
    cus = [s.run(_tiny) for _ in range(n)]
    for cu in cus:
        cu.result(timeout=60)
    return n / (time.perf_counter() - t0)


def _batched_rate(s: PilotSession, n: int, repeats: int = 3) -> float:
    """Batched path: one submit_tasks call, best of `repeats` (the gate
    measures the engine, not a cold first-touch of its worker threads)."""
    items = [_tiny] * n
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        batch = s.submit_tasks(items)
        assert batch.wait(timeout=120)
        rate = n / (time.perf_counter() - t0)
        best = max(best, rate)
    return best


def run(quick: bool = False):
    n_batch = N_BATCH_QUICK if quick else N_BATCH_FULL

    with PilotSession(name="bench-throughput") as s:
        s.add_pilot(task_workers=1)
        single = _single_rate(s, N_SINGLE)
        batched = _batched_rate(s, n_batch)
    speedup = batched / single if single > 0 else float("inf")

    with PilotSession(name="bench-throughput4") as s:
        s.add_pilots(4, task_workers=1)
        multi = _batched_rate(s, n_batch)

    common.emit("bench_throughput.single_cu", 1.0 / single,
                f"{single:,.0f}/s")
    common.emit("bench_throughput.batched", 1.0 / batched,
                f"{batched:,.0f}/s speedup={speedup:.1f}x")
    common.emit("bench_throughput.pilots4", 1.0 / multi,
                f"{multi:,.0f}/s")
    common.record("bench_throughput.batched",
                  tasks=n_batch,
                  tasks_per_s=batched,
                  single_tasks_per_s=single,
                  speedup_vs_single=speedup)
    common.record("bench_throughput.pilots4",
                  tasks=n_batch, pilots=4,
                  tasks_per_s=multi)
    return batched, single, speedup, multi


def gate(records) -> None:
    """The PR 6 guardrails (also wired into run.py's --quick gate)."""
    rows = {r["name"]: r for r in records}
    b = rows.get("bench_throughput.batched")
    if b is None:
        print("bench gate: no bench_throughput.batched record",
              file=sys.stderr)
        raise SystemExit(1)
    if b.get("tasks_per_s", 0.0) < THROUGHPUT_MIN_TASKS_PER_S:
        print(f"bench gate: batched engine only "
              f"{b.get('tasks_per_s'):,.0f} tasks/s "
              f"(target {THROUGHPUT_MIN_TASKS_PER_S:,.0f}/s)",
              file=sys.stderr)
        raise SystemExit(1)
    if b.get("speedup_vs_single", 0.0) < THROUGHPUT_MIN_SPEEDUP:
        print(f"bench gate: batched engine only "
              f"{b.get('speedup_vs_single'):.1f}x vs per-CU submission "
              f"(target {THROUGHPUT_MIN_SPEEDUP}x)", file=sys.stderr)
        raise SystemExit(1)
    m = rows.get("bench_throughput.pilots4")
    if m is None or m.get("tasks_per_s", 0.0) < THROUGHPUT_MIN_TASKS_PER_S:
        print("bench gate: 4-pilot batched run missing or below "
              f"{THROUGHPUT_MIN_TASKS_PER_S:,.0f} tasks/s", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick="--quick" in sys.argv)
    gate(common.records())
