"""Zero-copy transport plane: view-vs-copy fetch bandwidth + steady state.

The PR 8 tentpole gate.  Before the `Buf`/codec plane every fetch hop
materialized a fresh copy, so partition fetch bandwidth was set by memcpy
no matter how fast the serving tier was.  The plane now hands out
read-only views (mmap'd files, aliasing host views, dlpack device views)
and `copy_mode()` flips the SAME plane back into materialize-always reads
— so both sides of every comparison here run in one process against one
store, and the delta is exactly the memcpy the views elide.

Records (gated under ``--quick`` here and again by ``run.py``):

  * ``bench_transport.fetch`` — one >= 64 MiB file-tier partition,
    fetched as a view vs as a copy.  The view fetch must show >= 3x the
    copy fetch bandwidth (it is a header parse + page map; the copy is a
    full payload memcpy).  A fetch+consume row (fetch then sum every
    element) is recorded alongside for honesty: it includes the page
    faults the view defers;
  * ``bench_transport.mapreduce_steady`` — the pipelined map_reduce scan
    from the PR 6/7 benches over a file-backed working set, zero-copy vs
    copy mode.  Steady-state wall time must be no worse than the copy
    baseline (ratio <= 1.15 + jitter floor) — the plane must never make
    the existing benchmarks slower;
  * transport counters (`bytes_viewed`/`bytes_copied`, per-codec counts)
    ride along in the records, so the artifact shows the plane actually
    served views.
"""
from __future__ import annotations

import shutil
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, record

PART_MIB = 64                      # the gate's "large partition" floor
VIEW_MIN_SPEEDUP = 3.0             # view fetch vs copy fetch bandwidth
STEADY_MAX_RATIO = 1.25            # zero-copy wall / copy-mode wall ceiling


def _best(fn, repeats: int) -> float:
    b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        b = min(b, time.perf_counter() - t0)
    return b


def _bench_fetch(workdir: Path, quick: bool) -> float:
    from repro.core import DataUnit, copy_mode, make_backend

    nbytes = PART_MIB * 2 ** 20
    arr = np.arange(nbytes // 8, dtype=np.float64)
    du = DataUnit.from_partitions(
        "xfer", [arr], {"file": make_backend("file", root=workdir / "fetch")},
        tier="file")
    repeats = 5 if quick else 10
    # warm the page cache first: the comparison is view-vs-memcpy, not
    # cold-disk-vs-warm-disk
    with copy_mode():
        np.asarray(du.partition(0))

    t_view = _best(lambda: du.partition(0), repeats)

    def _copy_fetch():
        with copy_mode():
            du.partition(0)
    t_copy = _best(_copy_fetch, repeats)

    # fetch + consume: sum every element, so the view side pays its
    # deferred page faults inside the timer
    t_view_use = _best(lambda: float(np.sum(du.partition(0))), repeats)

    def _copy_use():
        with copy_mode():
            float(np.sum(du.partition(0)))
    t_copy_use = _best(_copy_use, repeats)

    gbps = lambda t: nbytes / max(t, 1e-9) / 2 ** 30   # noqa: E731
    speedup = t_copy / max(t_view, 1e-9)
    use_ratio = t_view_use / max(t_copy_use, 1e-9)
    emit("bench_transport.view_fetch", t_view,
         f"{gbps(t_view):,.1f}GiB/s part={PART_MIB}MiB")
    emit("bench_transport.copy_fetch", t_copy,
         f"{gbps(t_copy):,.1f}GiB/s speedup={speedup:.1f}x")
    emit("bench_transport.fetch_consume", t_view_use,
         f"view/copy={use_ratio:.2f}")
    record("bench_transport.fetch",
           part_mib=PART_MIB,
           view_seconds=t_view, copy_seconds=t_copy,
           view_gib_s=gbps(t_view), copy_gib_s=gbps(t_copy),
           speedup=speedup,
           consume_view_seconds=t_view_use,
           consume_copy_seconds=t_copy_use)
    return speedup


def _bench_mapreduce_steady(workdir: Path, quick: bool) -> float:
    import jax.numpy as jnp

    from repro.core import DataUnit, copy_mode, make_backend, map_reduce
    from repro.core.buf import STATS

    parts = 16 if quick else 32
    part_elems = (4 * 2 ** 20) // 8          # 4 MiB per partition
    pts = np.arange(parts * part_elems, dtype=np.float64)
    du = DataUnit.from_array(
        "steady", pts, parts,
        {"file": make_backend("file", root=workdir / "steady"),
         "host": make_backend("host")},
        tier="file")
    map_fn = lambda x: jnp.sum(x)            # noqa: E731
    red = lambda a, b: a + b                 # noqa: E731
    expect = float(np.sum(pts))

    def _scan():
        got = float(map_reduce(du, map_fn, red, pipeline=True))
        assert abs(got - expect) <= 1e-6 * abs(expect)

    _scan()                                  # warm jit + page cache
    repeats = 3 if quick else 5
    STATS.reset()
    t_view = _best(_scan, repeats)
    snap = STATS.snapshot()

    def _copy_scan():
        with copy_mode():
            _scan()
    _copy_scan()
    t_copy = _best(_copy_scan, repeats)

    ratio = t_view / max(t_copy, 1e-9)
    emit("bench_transport.mapreduce_steady", t_view,
         f"view/copy={ratio:.2f} parts={parts}")
    record("bench_transport.mapreduce_steady",
           parts=parts, view_seconds=t_view, copy_seconds=t_copy,
           ratio_vs_copy=ratio,
           bytes_viewed=snap["bytes_viewed"],
           bytes_copied=snap["bytes_copied"],
           codec=snap["codec"])
    return ratio


def run(quick: bool = False) -> None:
    root = Path(tempfile.mkdtemp(prefix="bench_transport_"))
    try:
        _bench_fetch(root, quick)
        _bench_mapreduce_steady(root, quick)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def gate(records) -> None:
    """The PR 8 guardrails (also wired into run.py's --quick gate)."""
    rows = {r["name"]: r for r in records}
    f = rows.get("bench_transport.fetch")
    if f is None:
        print("bench gate: no bench_transport.fetch record", file=sys.stderr)
        raise SystemExit(1)
    if f.get("part_mib", 0) < 64:
        print(f"bench gate: fetch partition only {f.get('part_mib')}MiB "
              "(gate requires >= 64MiB)", file=sys.stderr)
        raise SystemExit(1)
    if f.get("speedup", 0.0) < VIEW_MIN_SPEEDUP:
        print(f"bench gate: view fetch only {f.get('speedup'):.1f}x the "
              f"copy fetch (target {VIEW_MIN_SPEEDUP}x)", file=sys.stderr)
        raise SystemExit(1)
    m = rows.get("bench_transport.mapreduce_steady")
    if m is None:
        print("bench gate: no bench_transport.mapreduce_steady record",
              file=sys.stderr)
        raise SystemExit(1)
    if m.get("ratio_vs_copy", float("inf")) > STEADY_MAX_RATIO:
        print(f"bench gate: zero-copy steady-state map_reduce "
              f"{m.get('ratio_vs_copy'):.2f}x the copy-mode wall "
              f"(ceiling {STEADY_MAX_RATIO}x)", file=sys.stderr)
        raise SystemExit(1)
    if not m.get("bytes_viewed", 0):
        print("bench gate: steady-state run served zero view bytes "
              "(the zero-copy plane is not engaged)", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    from benchmarks import common
    print("name,us_per_call,derived")
    run(quick="--quick" in sys.argv)
    gate(common.records())
