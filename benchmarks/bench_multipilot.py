"""Multi-pilot distributed Pilot-Data: scaling the 2x-over-budget iterated
KMeans across pilots holding the SAME TOTAL device budget.

The single-pilot run owns the whole device budget but only half the
working set fits, so every iteration restages the overflow through that
pilot's throttled node-local disk (the adversarial LRU sequential scan
from bench_mapreduce).  The N-pilot run splits both the budget and — via
replica-aware map_reduce grouping — the partitions: each pilot's group
sticks to the replicas it already holds, so each pilot thrashes only its
own 1/N of the working set against its own disk, concurrently.  Restaged
bytes stay ~constant; the wall clock divides by the pilots' aggregate
node-local bandwidth (the paper's scale-out argument, and the two-level
storage paper's node-local replication win).

Rows: bench_multipilot.pilots<N>,us_per_run,derived; machine-readable
records (wall seconds, speedup vs 1 pilot, bytes staged/replicated) land
in BENCH_pr3.json via benchmarks.common.  CI gates on the 2-pilot run
being >= 1.3x the single-pilot wall clock.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, record

ITERS = 3
DEPTH = 4          # per-pilot pipeline depth = per-pilot stager width
K = 8


def _cold_profile(part_bytes: int, read_ms: float = 12.0,
                  write_ms: float = 0.3):
    """A node-local disk whose reads cost ~read_ms per partition and writes
    ~write_ms (restage-dominated, like bench_mapreduce's scenario A)."""
    from repro.core.memory import TierProfile
    return TierProfile("bench_cold_disk", simulate=True, latency=1e-3,
                       read_bw=part_bytes / (read_ms * 1e-3),
                       write_bw=part_bytes / (write_ms * 1e-3))


def _pilot_tm(root: Path, part_bytes: int, device_budget: int,
              host_budget: int):
    from repro.core import TierManager, make_backend
    from repro.core.memory import FileBackend
    return TierManager(
        {"file": FileBackend(root, _cold_profile(part_bytes)),
         "host": make_backend("host"),
         "device": make_backend("device")},
        {"device": device_budget, "host": host_budget},
        promote_threshold=0, max_workers=DEPTH)


def _run_kmeans(n_pilots: int, pts: np.ndarray, parts: int, workdir: Path):
    """One measured run: N pilots sharing one total device budget."""
    from repro.core import (ComputeDataManager, DataUnit,
                            PilotComputeDescription, PilotComputeService,
                            PilotDataService, kmeans, make_backend)

    part_bytes = pts.nbytes // parts
    total_device = (parts // 2) * part_bytes + part_bytes // 2  # half the set
    total_host = 3 * part_bytes                                 # forces disk
    svc = PilotComputeService()
    pds = PilotDataService()
    manager = ComputeDataManager(svc)
    pilots = []
    try:
        for p in range(n_pilots):
            pilot = svc.submit_pilot(PilotComputeDescription(
                backend="inprocess", stager_workers=DEPTH))
            pilot.attach_tier_manager(_pilot_tm(
                workdir / f"p{p}", part_bytes,
                total_device // n_pilots,
                max(total_host // n_pilots, part_bytes + part_bytes // 2)))
            pds.register_pilot(pilot)
            pilots.append(pilot)
        # home placement: unthrottled shared storage the pilots pull from
        du = pds.register(DataUnit.from_array(
            "mp-bench", pts, parts, {"host": make_backend("host")},
            tier="host"))
        t0 = time.perf_counter()
        r = kmeans(du, k=K, iters=ITERS, manager=manager,
                   prefetch_depth=DEPTH)
        wall = time.perf_counter() - t0
        for pilot in pilots:
            pilot.tier_manager.drain(timeout=60)
        staged = sum(
            p.tier_manager.counters["bytes_promoted"]
            + p.tier_manager.counters["bytes_demoted"] for p in pilots)
        return wall, float(r.sse_history[-1]), {
            "bytes_staged": staged,
            "replications": pds.counters["replications"]}
    finally:
        pds.close()
        svc.cancel_all()


def run(quick: bool = False) -> float:
    from repro.core import DataUnit, kmeans, make_backend, make_blobs

    n, parts = (16_000, 16) if quick else (48_000, 16)
    pts, _ = make_blobs(n, K, d=16, seed=0)

    # warm the jit cache so no run pays compile inside the timer
    warm = DataUnit.from_array(
        "warm", pts[: n // parts], 1,
        {"host": make_backend("host"), "device": make_backend("device")},
        tier="device")
    kmeans(warm, k=K, iters=1, seed=0)

    root = Path(tempfile.mkdtemp(prefix="bench_multipilot_"))
    results = {}
    try:
        for n_pilots in (1, 2) if quick else (1, 2, 4):
            results[n_pilots] = _run_kmeans(
                n_pilots, pts, parts, root / f"n{n_pilots}")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    wall_1, sse_1, stats_1 = results[1]
    emit("bench_multipilot.pilots1[sim]", wall_1, f"sse={sse_1:.3e}")
    record("bench_multipilot.pilots1", seconds=wall_1, pilots=1, **stats_1)
    speedup_2 = 0.0
    for n_pilots in sorted(results):
        if n_pilots == 1:
            continue
        wall, sse, stats = results[n_pilots]
        np.testing.assert_allclose(sse, sse_1, rtol=1e-3)
        speedup = wall_1 / max(wall, 1e-9)
        if n_pilots == 2:
            speedup_2 = speedup
        emit(f"bench_multipilot.pilots{n_pilots}[sim]", wall,
             f"speedup_vs_1={speedup:.2f}x depth={DEPTH}")
        record(f"bench_multipilot.pilots{n_pilots}", seconds=wall,
               pilots=n_pilots, speedup_vs_1=speedup, depth=DEPTH, **stats)
    if speedup_2 < 1.3:
        emit("bench_multipilot.WARNING", 0.0,
             f"2-pilot speedup {speedup_2:.2f}x below the 1.3x target")
    return speedup_2


if __name__ == "__main__":
    from benchmarks import common
    print("name,us_per_call,derived")
    run()
    common.write_json("BENCH_pr3.json", meta={"mode": "standalone"})
