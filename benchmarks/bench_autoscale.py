"""PR 10 elasticity bench: scale-out under burst, lossless scale-in,
priced rebalancing.

Three measurements, three gate clauses (the ROADMAP elasticity gate):

  * ``scale_out`` — a bursty sleep-task workload (waves of tasks
    arriving faster than one pilot drains them) runs once on a STATIC
    1-pilot fleet and once on an autoscaled fleet that starts identical
    (min 1, max ``MAX_PILOTS``, load-watermark policy).  The autoscaler
    must observe the backlog, grow mid-job, and beat the static fleet by
    ``MIN_SPEEDUP``x — the paper's elasticity argument measured end to
    end, with every scaling decision carrying the signal values that
    drove it.
  * ``scale_in`` — a 3-pilot fleet holding replicated + persisted
    DataUnits (every partition deliberately piled onto the victims)
    drains down to 1 pilot through the full protocol.  ZERO loss: every
    partition byte-identical to the source afterwards.
  * ``rebalance`` — every partition piled onto one donor, one pilot
    quarantined: the rebalancer must move partitions to the idle
    receiver, price every move through the InterconnectModel, and never
    touch the quarantined pilot.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import (Autoscaler, InterconnectModel, Link,
                        LoadScalingPolicy, PilotSession, Rebalancer)

MIN_SPEEDUP = 1.2       # elastic vs static-small wall time
MAX_PILOTS = 3
TASK_SLEEP_S = 0.004


def _work(_i: int) -> int:
    time.sleep(TASK_SLEEP_S)
    return _i


def _burst_workload(s: PilotSession, n_tasks: int, wave: int,
                    wave_gap_s: float) -> float:
    """Submit `n_tasks` sleep tasks in waves (so backlog builds between
    policy ticks) and return the wall time until ALL results landed."""
    t0 = time.perf_counter()
    batches = []
    for lo in range(0, n_tasks, wave):
        items = [(_work, (i,)) for i in range(lo, min(lo + wave, n_tasks))]
        batches.append(s.submit_tasks(items, timeout=120.0))
        time.sleep(wave_gap_s)
    got = []
    for b in batches:
        got.extend(b.results(timeout=120.0))
    assert got == list(range(n_tasks))
    return time.perf_counter() - t0


def _bench_scale_out(n_tasks: int, wave: int) -> dict:
    out = {}
    # static small fleet: 1 pilot, forever
    with PilotSession(name="bench-as-static") as s:
        s.add_pilots(1, memory_gb=0.05, task_workers=2)
        out["static_s"] = _burst_workload(s, n_tasks, wave, 0.01)
    # elastic fleet: starts identical, grows from the backlog signal
    with PilotSession(name="bench-as-elastic") as s:
        s.add_pilots(1, memory_gb=0.05, task_workers=2)
        a = Autoscaler(
            s, min_pilots=1, max_pilots=MAX_PILOTS,
            policy=LoadScalingPolicy(scale_out_load=1.0, hysteresis=1),
            interval_s=0.02, cooldown_s=0.05).start()
        try:
            out["elastic_s"] = _burst_workload(s, n_tasks, wave, 0.01)
            stats = a.stats()
        finally:
            a.close()
        out["end_pilots"] = stats["running"]
        out["scale_outs"] = stats["counters"]["scale_outs"]
        decisions = [d for d in stats["decisions"]
                     if d["action"].startswith("scale")]
        out["scaling_events"] = len(decisions)
        # the acceptance contract: every scaling event reports the
        # signal values, the action, and the victim/newcomer pilot
        out["decisions_with_signals"] = sum(
            1 for d in decisions
            if d["signals"].get("n_pilots") is not None and d["pilot"])
    out["speedup"] = (out["static_s"] / out["elastic_s"]
                      if out["elastic_s"] > 0 else float("inf"))
    return out


def _bench_scale_in(parts: int) -> dict:
    out = {}
    rng = np.random.default_rng(0)
    repl = rng.normal(size=(parts * 64, 8)).astype(np.float32)
    pers = rng.normal(size=(parts * 32, 4)).astype(np.float32)
    ckdir = tempfile.mkdtemp(prefix="bench-autoscale-in-")
    try:
        with PilotSession(name="bench-as-drain",
                          checkpoint_dir=ckdir) as s:
            s.add_pilots(3, memory_gb=0.05, host_memory_gb=0.5)
            du_r = s.data("replicated", repl, parts=parts, replication=2)
            du_p = s.data("persisted", pers, parts=parts, persist=True)
            a = Autoscaler(s, min_pilots=1, max_pilots=4)
            # pile every partition onto the pilots about to leave, so the
            # drain protocol must actually migrate / checkpoint-flush
            for du in (du_r, du_p):
                for p in s.pilots[:2]:
                    s.data_service.replicate_to_pilot(du, p.id,
                                                      tier="host")
            t0 = time.perf_counter()
            released = [a.scale_in(reason="bench"),
                        a.scale_in(reason="bench")]
            out["drain_s"] = time.perf_counter() - t0
            out["released"] = sum(1 for p in released if p is not None)
            out["end_pilots"] = len(s.pilots)
            evac = [d.detail.get("evacuated", {}) for d in a.decisions
                    if d.action == "scale-in"]
            out["migrated"] = sum(e.get("migrated", 0) for e in evac)
            out["flushed"] = sum(e.get("flushed", 0) for e in evac)
            out["evac_failed"] = sum(e.get("failed", 0) for e in evac)
            lost = 0
            for du, src in ((du_r, repl), (du_p, pers)):
                ref = np.array_split(src, parts, axis=0)
                for i in range(parts):
                    try:
                        if not np.array_equal(np.asarray(du.partition(i)),
                                              ref[i]):
                            lost += 1
                    except Exception:   # noqa: BLE001 - unreadable = lost
                        lost += 1
            out["lost_partitions"] = lost
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    return out


def _bench_rebalance(parts: int) -> dict:
    out = {}
    ic = InterconnectModel(default=Link(gbps=10.0, latency_s=1e-4))
    with PilotSession(name="bench-as-rebal", interconnect=ic) as s:
        pilots = s.add_pilots(3, memory_gb=0.05, host_memory_gb=0.5)
        donor, _receiver, sick = pilots
        rng = np.random.default_rng(1)
        ref = rng.normal(size=(parts * 64, 8)).astype(np.float32)
        du = s.data("skewed", ref, parts=parts)
        s.data_service.replicate_to_pilot(du, donor.id, tier="host")
        s.manager.policy.quarantine(sick.id)
        s.data_service.avoid_pilot(sick.id)
        r = Rebalancer(s, skew=1.2, max_moves=parts)
        t0 = time.perf_counter()
        moves = r.rebalance_once()
        out["rebalance_s"] = time.perf_counter() - t0
        done = [m for m in moves if m.status == "done"]
        out["moves"] = len(done)
        out["bytes_moved"] = sum(m.nbytes for m in done)
        out["unpriced_moves"] = sum(1 for m in done if m.cost_s <= 0)
        out["quarantined_touched"] = sum(
            1 for m in done if sick.id in (m.src, m.dst))
        src = np.array_split(ref, parts, axis=0)
        out["data_intact"] = all(
            np.array_equal(np.asarray(du.partition(i)), src[i])
            for i in range(parts))
    return out


def run(quick: bool = False):
    n_tasks = 240 if quick else 600
    wave = 24 if quick else 40
    parts = 6 if quick else 10

    # warmup: one tiny fleet cycle pays import/jit/provision overheads
    with PilotSession(name="bench-as-warmup") as s:
        s.add_pilots(1, memory_gb=0.05)
        s.submit_tasks([(_work, (0,))]).results(timeout=30.0)

    so = _bench_scale_out(n_tasks, wave)
    common.emit("bench_autoscale.static_small", so["static_s"],
                f"tasks={n_tasks} pilots=1")
    common.emit("bench_autoscale.scale_out", so["elastic_s"],
                f"speedup={so['speedup']:.2f}x "
                f"end_pilots={so['end_pilots']} "
                f"events={so['scaling_events']}")
    common.record("bench_autoscale.scale_out",
                  seconds=so["elastic_s"], static_seconds=so["static_s"],
                  speedup_vs_static=so["speedup"],
                  min_speedup=MIN_SPEEDUP,
                  end_pilots=so["end_pilots"], max_pilots=MAX_PILOTS,
                  scale_outs=so["scale_outs"],
                  scaling_events=so["scaling_events"],
                  decisions_with_signals=so["decisions_with_signals"],
                  n_tasks=n_tasks, wave=wave)

    si = _bench_scale_in(parts)
    common.emit("bench_autoscale.scale_in", si["drain_s"],
                f"released={si['released']} migrated={si['migrated']} "
                f"flushed={si['flushed']} lost={si['lost_partitions']}")
    common.record("bench_autoscale.scale_in",
                  seconds=si["drain_s"], released=si["released"],
                  end_pilots=si["end_pilots"], migrated=si["migrated"],
                  flushed=si["flushed"], evac_failed=si["evac_failed"],
                  lost_partitions=si["lost_partitions"], parts=parts)

    rb = _bench_rebalance(parts)
    common.emit("bench_autoscale.rebalance", rb["rebalance_s"],
                f"moves={rb['moves']} bytes={rb['bytes_moved']} "
                f"intact={rb['data_intact']}")
    common.record("bench_autoscale.rebalance",
                  seconds=rb["rebalance_s"], moves=rb["moves"],
                  bytes_moved=rb["bytes_moved"],
                  unpriced_moves=rb["unpriced_moves"],
                  quarantined_touched=rb["quarantined_touched"],
                  data_intact=rb["data_intact"], parts=parts)


def gate(records) -> None:
    """CI guardrails for the elasticity path (raises SystemExit)."""
    import sys
    rows = {r["name"]: r for r in records}

    so = rows.get("bench_autoscale.scale_out")
    if so is None:
        print("bench gate: no bench_autoscale.scale_out record",
              file=sys.stderr)
        raise SystemExit(1)
    if so.get("speedup_vs_static", 0.0) < MIN_SPEEDUP:
        print(f"bench gate: elastic fleet only "
              f"{so.get('speedup_vs_static'):.2f}x static-small "
              f"(floor {MIN_SPEEDUP}x)", file=sys.stderr)
        raise SystemExit(1)
    if so.get("scale_outs", 0) < 1:
        print("bench gate: the autoscaler never scaled out",
              file=sys.stderr)
        raise SystemExit(1)
    if so.get("decisions_with_signals", 0) < so.get("scaling_events", 1):
        print("bench gate: scaling decisions missing signal values or "
              "pilot ids", file=sys.stderr)
        raise SystemExit(1)

    si = rows.get("bench_autoscale.scale_in")
    if si is None:
        print("bench gate: no bench_autoscale.scale_in record",
              file=sys.stderr)
        raise SystemExit(1)
    if si.get("lost_partitions", 1) != 0 or si.get("evac_failed", 1) != 0:
        print(f"bench gate: scale-in LOST DATA "
              f"(lost={si.get('lost_partitions')} "
              f"evac_failed={si.get('evac_failed')})", file=sys.stderr)
        raise SystemExit(1)
    if si.get("released", 0) != 2:
        print(f"bench gate: expected 2 drained releases, got "
              f"{si.get('released')}", file=sys.stderr)
        raise SystemExit(1)

    rb = rows.get("bench_autoscale.rebalance")
    if rb is None:
        print("bench gate: no bench_autoscale.rebalance record",
              file=sys.stderr)
        raise SystemExit(1)
    if rb.get("moves", 0) < 1:
        print("bench gate: the rebalancer executed no migrations",
              file=sys.stderr)
        raise SystemExit(1)
    if rb.get("unpriced_moves", 1) != 0:
        print("bench gate: rebalance migrations not priced by the "
              "interconnect", file=sys.stderr)
        raise SystemExit(1)
    if rb.get("quarantined_touched", 1) != 0:
        print("bench gate: rebalance touched a quarantined pilot",
              file=sys.stderr)
        raise SystemExit(1)
    if not rb.get("data_intact"):
        print("bench gate: rebalance corrupted partition data",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
    gate(common.records())
