"""Shared benchmark utilities: timing, CSV emission, JSON records.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = the
figure-relevant quantity: bandwidth, speedup, roofline term, ...) and may
additionally `record()` machine-readable rows; `write_json()` dumps the
accumulated records (per-benchmark wall time, bytes staged, evictions, ...)
to a ``BENCH_*.json`` artifact so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import platform
import sys
import time
from pathlib import Path
from typing import List, Optional

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

_RECORDS: List[dict] = []


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kwargs):
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    return min(times)


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def record(name: str, **fields) -> dict:
    """Accumulate one machine-readable benchmark row (seconds, bytes
    staged, evictions, speedups, ...) for the JSON artifact."""
    row = {"name": name}
    row.update(fields)
    _RECORDS.append(row)
    return row


def records() -> List[dict]:
    return list(_RECORDS)


def write_json(path: str | Path, meta: Optional[dict] = None) -> Path:
    doc = {
        "schema": "repro-bench.v1",
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    if meta:
        doc.update(meta)
    doc["benchmarks"] = list(_RECORDS)
    path = Path(path)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path
