"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = the
figure-relevant quantity: bandwidth, speedup, roofline term, ...).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kwargs):
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    return min(times)


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
