"""Pilot-API v2 bench: cross-pilot sibling reads vs home re-pull.

The ROADMAP item behind the PR 5 redesign: a CU bound to pilot B that
needs partitions pilot A already holds should read them over the
(modelled) interconnect instead of re-pulling from the home store.  Here
the home placement is a throttled file store (the paper's simulated
Stampede-disk shared filesystem), pilot A holds a full replica of the
working set, and pilot B pulls every partition through:

  * ``home``    — no InterconnectModel: every pull goes back to the slow
                  home store first (the PR 3 order);
  * ``sibling`` — InterconnectModel attached (fast fabric, slow home
                  model, simulate=True so sibling transfers charge their
                  modelled cost): every pull is served from A's memory.

The gate asserts sibling reads actually won AND were measurably faster.
A second record drives the full multi-pilot KMeans through the
PilotSession façade end-to-end (the acceptance path).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import (InterconnectModel, PROFILES, PilotSession,
                        make_blobs)


def _pull_workload(interconnect, parts: int, rows: int, tag: str):
    """Seed pilot A with a full replica, then time pilot B pulling every
    partition through the data service."""
    pts = np.arange(parts * rows * 8, dtype=np.float32).reshape(-1, 8)
    with PilotSession(interconnect=interconnect,
                      name=f"bench-{tag}") as s:
        a = s.add_pilot(memory_gb=0.25)
        b = s.add_pilot(memory_gb=0.25)
        du = s.data("ws", pts, parts=parts, tier="file",
                    profile=PROFILES["stampede_disk"])
        du.replicate_to_pilot(a)        # seeded once, outside the timing
        t0 = time.perf_counter()
        for i in range(parts):
            du.partition(i, pilot=b)
        dt = time.perf_counter() - t0
        counters = dict(s.data_service.counters)
    return dt, counters


def run(quick: bool = False):
    parts = 6 if quick else 8
    rows = 8_192 if quick else 32_768   # 256KB / 1MB partitions

    t_home, c_home = _pull_workload(None, parts, rows, "home")
    t_sib, c_sib = _pull_workload(InterconnectModel(simulate=True),
                                  parts, rows, "sibling")
    speedup = t_home / t_sib if t_sib > 0 else float("inf")
    common.emit("bench_session.home_repull", t_home,
                f"parts={parts}")
    common.emit("bench_session.sibling_reads", t_sib,
                f"speedup_vs_home={speedup:.2f}x "
                f"sibling={c_sib['sibling_reads']}")
    common.record("bench_session.sibling_reads",
                  seconds=t_sib, home_seconds=t_home,
                  speedup_vs_home=speedup, parts=parts,
                  sibling_reads=c_sib["sibling_reads"],
                  home_reads_costed=c_sib["home_reads"],
                  home_variant_sibling_reads=c_home["sibling_reads"])

    # façade end-to-end: multi-pilot KMeans through PilotSession
    pts, _ = make_blobs(20_000 if quick else 60_000, 8, d=8, seed=0)
    t0 = time.perf_counter()
    with PilotSession(name="bench-facade") as s:
        pilots = s.add_pilots(2, memory_gb=0.1)
        du = s.data("pts", pts, parts=8)
        du.replicate_to_pilot(pilots[0], parts=range(0, 4))
        du.replicate_to_pilot(pilots[1], parts=range(4, 8))
        res = s.kmeans(du, k=8, iters=3)
        used = len(s.manager.stats()["per_pilot"])
    dt = time.perf_counter() - t0
    common.emit("bench_session.facade_kmeans", dt,
                f"pilots_used={used} sse={res.sse_history[-1]:.1f}")
    common.record("bench_session.facade_kmeans", seconds=dt,
                  completed=True, pilots_used=used,
                  sse=float(res.sse_history[-1]))


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
