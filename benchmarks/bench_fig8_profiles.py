"""Paper Fig. 8: Gordon (flash) vs Stampede (disk) storage hierarchies.

Paper: HDFS on Gordon's local flash beats Stampede's disks; the
flash->memory speedup is smaller than the disk->memory one. Reproduced with
the published-order bandwidth profiles (SIMULATED) against the real host
tier: derived column reports the tier->memory speedup, whose ORDERING
(disk/mem > flash/mem > 1) is the paper's claim.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.memory import PROFILES, FileBackend, HostMemoryBackend


def run(tmp_root: str = "/tmp/repro_bench_fig8", mb: int = 16):
    rng = np.random.default_rng(0)
    arr = rng.normal(size=(mb * 1024 * 1024 // 4,)).astype(np.float32)
    host = HostMemoryBackend()
    host.put("x", arr)
    t_mem = timeit(lambda: host.get("x"), repeats=3)
    results = {}
    for name, profile in (("stampede_disk", PROFILES["stampede_disk"]),
                          ("gordon_flash", PROFILES["gordon_flash"])):
        be = FileBackend(f"{tmp_root}/{name}", profile)
        be.put("x", arr)
        t = timeit(lambda: be.get("x"), repeats=2)
        results[name] = t
        emit(f"fig8_read/{name}/{mb}MB", t,
             f"speedup_to_mem={t / t_mem:.1f}x(SIMULATED)")
    emit(f"fig8_read/memory/{mb}MB", t_mem, "1.0x")
    assert results["stampede_disk"] > results["gordon_flash"] > t_mem, \
        "paper ordering violated"


if __name__ == "__main__":
    run()
