"""Kernel microbenches: oracle wall-time on this host + interpret-mode
equivalence deltas (the TPU perf claim lives in the roofline analysis; this
bench guards CPU-side correctness/perf regressions of the oracles)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.kmeans.ref import kmeans_assign_ref
from repro.kernels.selective_scan.ref import selective_scan_ref


def run():
    # kmeans map phase (paper's hot-spot): flops-normalized
    pts = jax.random.normal(jax.random.key(0), (100_000, 8), jnp.float32)
    cen = jax.random.normal(jax.random.key(1), (50, 8), jnp.float32)
    f = jax.jit(kmeans_assign_ref)
    t = timeit(lambda: jax.block_until_ready(f(pts, cen)))
    flops = 2 * 100_000 * 50 * 8 * 2
    emit("kernel/kmeans_ref/100kx50", t, f"{flops / t / 1e9:.1f}GFLOP/s")

    q = jax.random.normal(jax.random.key(0), (1, 1024, 8, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, 1024, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, 1024, 2, 64), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    t = timeit(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * 1024 * 1024 * 8 * 64 / 2
    emit("kernel/attention_ref/1k", t, f"{flops / t / 1e9:.1f}GFLOP/s")

    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (2, 512, 256), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 512, 256)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[2], (256, 16)))
    b = jax.random.normal(ks[3], (2, 512, 16))
    c = jax.random.normal(ks[4], (2, 512, 16))
    d = jnp.ones((256,))
    f = jax.jit(selective_scan_ref)
    t = timeit(lambda: jax.block_until_ready(f(x, dt, a, b, c, d)))
    emit("kernel/selective_scan_ref/512", t,
         f"{2 * 512 * 256 * 16 * 2 / t / 1e9:.2f}GFLOP/s")


if __name__ == "__main__":
    run()
