"""Roofline table: re-emit the dry-run sweep's per-cell terms as bench rows.

Reads experiments/dryrun/*.json (produced by ``python -m
repro.launch.dryrun --all``). Derived: the three terms + bottleneck.
us_per_call is the roofline step time (max of the three terms) in us.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run():
    if not DRYRUN_DIR.exists():
        emit("roofline/missing", 0.0, "run: python -m repro.launch.dryrun --all")
        return
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") != "ok":
            emit(name, 0.0, r.get("status", "?"))
            continue
        ro = r["roofline"]
        step = max(ro["t_compute"], ro["t_memory"], ro["t_collective"])
        emit(name, step,
             f"bneck={ro['bottleneck']} frac={ro['roofline_fraction']:.3f} "
             f"useful={ro['useful_flops_ratio']:.2f} "
             f"peakGiB={ro['peak_mem_bytes'] / 2**30:.1f}")


if __name__ == "__main__":
    run()
