"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes the machine-readable records (per-benchmark wall time, bytes staged,
evictions) to a JSON artifact (default ``BENCH_pr10.json``; override with
``--json PATH``) so the perf trajectory is tracked across PRs.

``--quick`` is the CI smoke path: it runs the tiering, map_reduce,
multi-pilot, checkpoint, session, throughput, resilience, and transport
benches,
writes the artifact, and exits non-zero if the pipelined map_reduce
engine is slower than the sequential baseline, the 2-pilot distributed
Pilot-Data run is below 1.3x the single-pilot wall clock on the
2x-over-budget workload, the 3x-over-budget checkpoint-tier workload
fails to complete / loses to naive re-staging from the original file
store, cost-modelled cross-pilot sibling reads fail to beat re-pulling
from a simulated slow home store, the batched task engine misses its
>=10^5 tasks/s and >=20x-over-per-CU throughput floor, or the chaos
kill-one-of-N resilience storm loses data / fails to restore
replication / exceeds 1.5x the fault-free wall time, or the zero-copy
plane misses its >= 3x view-over-copy fetch floor / regresses the
steady-state map_reduce past the copy-mode baseline, or substrate LM
serving exceeds 1.5x the isolated stack's p99 / loses requests or
token-count exactness under the chaos kill, or the elastic autoscaler
fails to beat the static-small fleet >= 1.2x under burst / loses a
partition on scale-in / executes unpriced or quarantine-touching
rebalance migrations.
"""
from __future__ import annotations

import sys
import traceback
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

DEFAULT_JSON = "BENCH_pr10.json"
MULTIPILOT_MIN_SPEEDUP = 1.3
CHECKPOINT_MIN_SPEEDUP = 1.0
SESSION_MIN_SPEEDUP = 1.5


def _json_path(argv) -> str:
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 < len(argv):
            return argv[i + 1]
    return DEFAULT_JSON


def _gate(records) -> None:
    """CI guardrails: the pipelined engine must not lose to sequential, and
    2 pilots must beat 1 pilot >= 1.3x on the over-budget workload."""
    rows = {r["name"]: r for r in records}
    pipe = rows.get("bench_mapreduce.pipelined")
    if pipe is None:
        print("bench gate: no bench_mapreduce.pipelined record",
              file=sys.stderr)
        raise SystemExit(1)
    if pipe.get("speedup", 0.0) < 1.0:
        print(f"bench gate: pipelined map_reduce slower than sequential "
              f"({pipe.get('speedup'):.2f}x)", file=sys.stderr)
        raise SystemExit(1)
    mp = rows.get("bench_multipilot.pilots2")
    if mp is None:
        print("bench gate: no bench_multipilot.pilots2 record",
              file=sys.stderr)
        raise SystemExit(1)
    if mp.get("speedup_vs_1", 0.0) < MULTIPILOT_MIN_SPEEDUP:
        print(f"bench gate: 2-pilot map_reduce only "
              f"{mp.get('speedup_vs_1'):.2f}x vs 1 pilot "
              f"(target {MULTIPILOT_MIN_SPEEDUP}x)", file=sys.stderr)
        raise SystemExit(1)
    ck = rows.get("bench_checkpoint.tiered")
    if ck is None:
        print("bench gate: no bench_checkpoint.tiered record",
              file=sys.stderr)
        raise SystemExit(1)
    if not ck.get("completed"):
        print("bench gate: 3x-over-budget checkpoint workload did not "
              "complete", file=sys.stderr)
        raise SystemExit(1)
    if ck.get("speedup_vs_restage", 0.0) < CHECKPOINT_MIN_SPEEDUP:
        print(f"bench gate: checkpoint tier "
              f"{ck.get('speedup_vs_restage'):.2f}x vs naive re-staging "
              f"(target {CHECKPOINT_MIN_SPEEDUP}x)", file=sys.stderr)
        raise SystemExit(1)
    ss = rows.get("bench_session.sibling_reads")
    if ss is None:
        print("bench gate: no bench_session.sibling_reads record",
              file=sys.stderr)
        raise SystemExit(1)
    if not ss.get("sibling_reads", 0):
        print("bench gate: interconnect run served zero sibling reads",
              file=sys.stderr)
        raise SystemExit(1)
    if ss.get("speedup_vs_home", 0.0) < SESSION_MIN_SPEEDUP:
        print(f"bench gate: cross-pilot sibling reads only "
              f"{ss.get('speedup_vs_home'):.2f}x vs home re-pull "
              f"(target {SESSION_MIN_SPEEDUP}x)", file=sys.stderr)
        raise SystemExit(1)
    fk = rows.get("bench_session.facade_kmeans")
    if fk is None or not fk.get("completed"):
        print("bench gate: PilotSession façade KMeans did not complete",
              file=sys.stderr)
        raise SystemExit(1)
    # PR 6: the batched task engine must sustain >= 10^5 tiny tasks/s and
    # >= 20x the per-CU submission rate (details in bench_throughput)
    from benchmarks import bench_throughput
    bench_throughput.gate(records)
    # PR 7: chaos-kill one of N pilots mid-KMeans — zero data loss,
    # replication restored, >= 1 respawn, <= 1.5x fault-free wall time
    from benchmarks import bench_resilience
    bench_resilience.gate(records)
    # PR 8: the zero-copy plane — view fetch >= 3x copy fetch on >= 64MiB
    # partitions, steady-state map_reduce no worse than the copy baseline
    from benchmarks import bench_transport
    bench_transport.gate(records)
    # PR 9: LM serving ON the substrate — p99 <= 1.5x the isolated stack
    # at equal batch, exact token accounting, chaos kill loses nothing
    from benchmarks import bench_serving
    bench_serving.gate(records)
    # PR 10: elasticity — burst scale-out >= 1.2x static-small, scale-in
    # drains with zero partition loss, rebalance migrations priced and
    # never sourced from a quarantined pilot
    from benchmarks import bench_autoscale
    bench_autoscale.gate(records)


def main() -> None:
    from benchmarks import (bench_autoscale, bench_checkpoint,
                            bench_fig6_startup, bench_fig7_storage,
                            bench_fig8_profiles, bench_fig9_kmeans,
                            bench_kernels, bench_mapreduce,
                            bench_multipilot, bench_resilience,
                            bench_roofline, bench_serving, bench_session,
                            bench_throughput, bench_tiering,
                            bench_train_step, bench_transport)
    from benchmarks import common
    quick = "--quick" in sys.argv
    json_path = _json_path(sys.argv)
    print("name,us_per_call,derived")
    if quick:
        # CI smoke: the tiering + map_reduce + multipilot + checkpoint +
        # session benches exercise pilots, DUs, the managed hierarchy,
        # eviction policies, the pipelined engine, the distributed
        # Pilot-Data layer, the durable spill/restore path, and the v2
        # façade + cross-pilot interconnect reads end-to-end in seconds
        bench_tiering.run(quick=True)
        bench_mapreduce.run(quick=True)
        bench_multipilot.run(quick=True)
        bench_checkpoint.run(quick=True)
        bench_session.run(quick=True)
        bench_throughput.run(quick=True)
        bench_resilience.run(quick=True)
        bench_transport.run(quick=True)
        bench_serving.run(quick=True)
        bench_autoscale.run(quick=True)
        common.write_json(json_path, meta={"mode": "quick"})
        print(f"# wrote {json_path}", file=sys.stderr)
        _gate(common.records())
        return
    failures = 0
    for mod in (bench_fig6_startup, bench_fig7_storage, bench_fig8_profiles,
                bench_fig9_kmeans, bench_kernels, bench_tiering,
                bench_mapreduce, bench_multipilot, bench_checkpoint,
                bench_session, bench_throughput, bench_resilience,
                bench_transport, bench_serving, bench_autoscale,
                bench_train_step, bench_roofline):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    common.write_json(json_path, meta={"mode": "full"})
    print(f"# wrote {json_path}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
