"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
from __future__ import annotations

import sys
import traceback
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def main() -> None:
    from benchmarks import (bench_fig6_startup, bench_fig7_storage,
                            bench_fig8_profiles, bench_fig9_kmeans,
                            bench_kernels, bench_roofline, bench_tiering,
                            bench_train_step)
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    if quick:
        # CI smoke: the tiering bench exercises pilots, DUs, the managed
        # hierarchy, and the KMeans path end-to-end in a few seconds
        bench_tiering.run(quick=True)
        return
    failures = 0
    for mod in (bench_fig6_startup, bench_fig7_storage, bench_fig8_profiles,
                bench_fig9_kmeans, bench_kernels, bench_tiering,
                bench_train_step, bench_roofline):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
