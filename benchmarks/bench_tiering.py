"""Working-set-exceeds-memory KMeans: managed hierarchy vs all-file baseline.

The paper's 212x KMeans win (§4.3) comes from keeping points memory-resident
across iterations. This bench stresses the harder case the flat tiers could
not express: the working set is 2x the device-tier budget, so *unmanaged*
HBM residency is impossible. The TierManager keeps the hot half pinned-by-
heat in device/host memory and demotes the rest, while the baseline re-reads
every partition from the (simulated Stampede-disk-throttled) file tier each
iteration. Managed must win despite holding only half the set in HBM.

Rows: bench_tiering.<variant>,us_per_run,derived (derived = speedup or
peak-device-usage/budget).
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

from benchmarks.common import emit, record

ITERS = 4
K = 8


def _datasets(quick: bool):
    n = 8_000 if quick else 48_000
    parts = 4 if quick else 8
    return n, parts


def run(quick: bool = False) -> None:
    from repro.core import DataUnit, TierManager, kmeans, make_backend, make_blobs
    from repro.core.memory import PROFILES, FileBackend

    n, parts = _datasets(quick)
    pts, _ = make_blobs(n, K, d=16, seed=0)
    part_bytes = pts.nbytes // parts
    budget = (parts // 2) * part_bytes + part_bytes // 2   # half the set + slack
    root = Path(tempfile.mkdtemp(prefix="bench_tiering_"))
    try:
        # baseline: every iteration restages from throttled disk (paper's
        # file backend; profile marked simulated in memory.PROFILES)
        file_be = {"file": FileBackend(root / "base",
                                       PROFILES["stampede_disk"]),
                   "host": make_backend("host")}
        du_file = DataUnit.from_array("base", pts, parts, file_be, tier="file")
        t0 = time.perf_counter()
        r_file = kmeans(du_file, k=K, iters=ITERS, seed=0)
        t_file = time.perf_counter() - t0

        # managed: device budget = half the working set; LRU demotion +
        # heat promotion + async prefetch keep the hot half resident
        tm = TierManager({"file": make_backend("file", root=root / "tm"),
                          "host": make_backend("host"),
                          "device": make_backend("device")},
                         {"device": budget}, promote_threshold=2)
        du_tm = DataUnit.from_array("managed", pts, parts, tm.backends,
                                    tier="device", tier_manager=tm)
        t0 = time.perf_counter()
        r_tm = kmeans(du_tm, k=K, iters=ITERS, seed=0)
        t_tm = time.perf_counter() - t0
        tm.drain(timeout=60)

        speedup = t_file / max(t_tm, 1e-9)
        emit("bench_tiering.file_baseline[sim]", t_file,
             f"sse={r_file.sse_history[-1]:.3e}")
        emit("bench_tiering.managed_2x_budget", t_tm,
             f"speedup={speedup:.1f}x")
        emit("bench_tiering.device_peak", 0.0,
             f"peak/budget={tm.peak_usage('device')}/{budget}")
        summary = tm.event_summary()
        record("bench_tiering.file_baseline", seconds=t_file)
        record("bench_tiering.managed_2x_budget", seconds=t_tm,
               speedup=speedup, evictions=summary["demotions"],
               bytes_staged=(summary["bytes_promoted"]
                             + summary["bytes_demoted"]),
               device_peak=tm.peak_usage("device"), device_budget=budget)
        assert tm.peak_usage("device") <= budget, "device budget exceeded"
        tm.close()
        if speedup <= 1.0:
            emit("bench_tiering.WARNING", 0.0,
                 "managed hierarchy did not beat file baseline")
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
