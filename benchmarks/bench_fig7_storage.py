"""Paper Fig. 7: read/write performance across storage backends vs size.

Paper: HDFS vs Lustre on Stampede — Lustre wins small transfers, HDFS wins
large parallel reads. Here: the tier ladder (file-native, file@hdfs-profile,
file@lustre-profile, host, device) over 1..32 MiB DataUnits. Profiled tiers
are SIMULATED (published-order bandwidth models); native/host/device are
real measurements on this machine. Derived: MB/s.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import DataUnit, make_backend
from repro.core.memory import PROFILES, FileBackend

SIZES_MB = (1, 8, 32)


def run(tmp_root: str = "/tmp/repro_bench_fig7"):
    backends_all = {
        "file": make_backend("file", root=f"{tmp_root}/native"),
        "hdfs(sim)": FileBackend(f"{tmp_root}/hdfs", PROFILES["hdfs"]),
        "lustre(sim)": FileBackend(f"{tmp_root}/lustre", PROFILES["lustre"]),
        "host": make_backend("host"),
        "device": make_backend("device"),
    }
    rng = np.random.default_rng(0)
    for mb in SIZES_MB:
        arr = rng.normal(size=(mb * 1024 * 1024 // 4,)).astype(np.float32)
        for tier, be in backends_all.items():
            t_w = timeit(lambda: be.put("x", arr), repeats=2)
            t_r = timeit(lambda: be.get("x"), repeats=2)
            sim = "(SIMULATED)" if "sim" in tier else ""
            emit(f"fig7_write/{tier}/{mb}MB", t_w, f"{mb / t_w:.0f}MB/s{sim}")
            emit(f"fig7_read/{tier}/{mb}MB", t_r, f"{mb / t_r:.0f}MB/s{sim}")
            be.delete("x")


if __name__ == "__main__":
    run()
