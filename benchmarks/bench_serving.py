"""PR 9 serving bench: LM serving on the pilot substrate vs an isolated
stack, plus a chaos-kill mid-stream recovery storm.

Three measurements on the smoke llama config under OPEN-LOOP Poisson
arrivals (one shared, precomputed schedule — the arrival process never
adapts to either system's speed, so a slow server builds queueing delay
instead of quietly throttling the workload):

  * ``baseline``  — an isolated continuous-batching loop: params in loop
    locals, plain ``jax.jit``, no session, no durability.  The strongest
    fair rival: same model, same batch geometry, same splice/sample
    helpers, zero substrate overhead.
  * ``substrate`` — the same requests through ``ServingEngine`` on ONE
    pilot at EQUAL batch size: shards + KV pages as tiered Pilot-Data
    partitions, replica routing, resident decode task, page flushes.
    The gate bounds the abstraction tax: p99 latency <= 1.5x baseline,
    every request completed with EXACT per-request token counts.
  * ``chaos``     — 3 pilots (the victim on the simulated backend),
    supervised session, durable checkpoint home.  Once tokens are
    flowing the victim is chaos-killed (state FAILED, volatile tiers
    wiped) through the same event machinery as bench_resilience; the
    gate demands every request still completes with exact counts — zero
    data loss — plus >= 1 supervisor respawn and >= 1 replica death.
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import PilotSession
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import (ChaosEvent, ChaosPolicy,
                                           SimulatedClusterBackend)
from repro.launch.train import scaled_config
from repro.models.model import build_model
from repro.serving import sample_tokens, splice_row, ServingEngine

MAX_P99_RATIO = 1.5         # substrate p99 vs isolated-stack p99
UTILIZATION = 0.6           # Poisson rate as a fraction of row capacity


def _p99(xs):
    xs = sorted(xs)
    return xs[max(0, int(np.ceil(0.99 * len(xs))) - 1)]


def _arrivals(n: int, rate_hz: float, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_hz, size=n))


def _step_seconds(model, params, batch: int, plen: int, max_len: int):
    """Warm-timed decode step at this batch geometry (compile excluded)."""
    pf = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, max_len))
    dec = jax.jit(model.decode)
    toks = jnp.zeros((batch, plen), jnp.int32)
    logits, cache = pf(params, toks)
    pos = jnp.full((batch,), plen - 1, jnp.int32)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits, cache = dec(params, cache, cur, pos + 1)   # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    steps = 5
    for i in range(steps):
        logits, cache = dec(params, cache, cur, pos + 2 + i)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / steps


def _baseline(model, params, prompts, gen: int, arrivals, batch: int,
              max_len: int):
    """Isolated stack: the fixed continuous-batching loop with nothing
    under it — admission honors the arrival schedule in real time."""
    pf = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, max_len))
    dec = jax.jit(model.decode, donate_argnums=(1,))
    pending = list(range(len(prompts)))
    rows = [None] * batch
    row_out = [[] for _ in range(batch)]
    positions = np.zeros(batch, np.int32)
    cache = logits = None
    key = jax.random.key(1)
    outs = [None] * len(prompts)
    lat = [0.0] * len(prompts)
    t0 = time.perf_counter()
    while pending or any(r is not None for r in rows):
        now = time.perf_counter() - t0
        free = [r for r in range(batch) if rows[r] is None]
        for r in free:
            if not pending or arrivals[pending[0]] > now:
                break
            i = pending.pop(0)
            if cache is None:
                wave = [i]
                while (len(wave) < batch and pending
                       and arrivals[pending[0]] <= now):
                    wave.append(pending.pop(0))
                ctxs = [prompts[j] for j in wave]
                while len(ctxs) < batch:
                    ctxs.append(ctxs[0])        # padding rows stay inactive
                logits, cache = pf(params, jnp.asarray(np.stack(ctxs)))
                for rr, j in enumerate(wave):
                    rows[rr] = j
                    positions[rr] = len(prompts[j]) - 1
                break
            row_logits, row_cache = pf(params,
                                       jnp.asarray(prompts[i][None, :]))
            cache = splice_row(cache, row_cache, r)
            logits = logits.at[r].set(row_logits[0])
            rows[r] = i
            row_out[r] = []
            positions[r] = len(prompts[i]) - 1
        active = np.array([q is not None for q in rows])
        if not active.any():
            if pending:
                time.sleep(min(0.005,
                               max(0.0, arrivals[pending[0]] - now)))
            continue
        tok, key = sample_tokens(logits, jnp.asarray(active), key, 0.0)
        tok_np = np.asarray(tok)
        done_now = time.perf_counter() - t0
        for r in range(batch):
            if rows[r] is None:
                continue
            row_out[r].append(int(tok_np[r]))
            if len(row_out[r]) >= gen:
                i = rows[r]
                outs[i] = list(row_out[r])
                lat[i] = done_now - arrivals[i]
                rows[r] = None
                row_out[r] = []
        if any(q is not None for q in rows):
            still = np.array([q is not None for q in rows])
            positions[still] += 1
            logits, cache = dec(params, cache, tok[:, None],
                                jnp.asarray(positions))
    return outs, lat


def _substrate(model, params, prompts, gen: int, arrivals, batch: int,
               max_len: int):
    """Same requests, same schedule, through the pilot substrate."""
    with PilotSession(name="bench-serving") as s:
        s.add_pilots(1, memory_gb=0.5, affinity="server")
        with ServingEngine(s, model, params=params, batch_size=batch,
                           max_len=max_len, name="bserve") as eng:
            eng.deploy()
            t0 = time.perf_counter()
            reqs = []
            for i, p in enumerate(prompts):
                wait = arrivals[i] - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(wait)
                reqs.append(eng.submit(p, gen))
            eng.drain(timeout=600)
            outs = [r.result(timeout=10) for r in reqs]
            lat = [r.latency_s for r in reqs]
            st = eng.stats()
    return outs, lat, st


def _chaos(model, params, prompts, gen: int, batch: int, max_len: int):
    """Kill the victim pilot mid-stream; every request must survive."""
    register_backend(SimulatedClusterBackend(
        substrate="slurm", policy=ChaosPolicy(lose_memory=True,
                                              target_index=0)))
    ckdir = tempfile.mkdtemp(prefix="bench-serving-chaos-")
    out = {}
    try:
        with PilotSession(name="bench-serving-chaos", supervise=True,
                          checkpoint_dir=ckdir,
                          supervisor_kwargs={"interval_s": 0.02,
                                             "min_heartbeat_s": 0.05,
                                             "repair_interval_s": 0.05}) as s:
            victim = s.add_pilot(backend="simulated", startup_seconds=0.01,
                                 memory_gb=0.5, affinity="server")
            s.add_pilots(2, memory_gb=0.5, affinity="server")
            with ServingEngine(s, model, params=params, batch_size=batch,
                               max_len=max_len, name="cserve",
                               page_tokens=4) as eng:
                eng.deploy()
                t0 = time.perf_counter()
                reqs = [eng.submit(p, gen) for p in prompts]
                # arm the kill only once tokens are flowing, so it lands
                # mid-stream deterministically (same firing path as
                # bench_resilience: the supervisor's next health probe
                # discovers the corpse)
                deadline = time.monotonic() + 60.0
                while time.monotonic() < deadline:
                    if eng.counters["tokens_served"] >= 2 * batch:
                        break
                    time.sleep(0.01)
                victim.arm_chaos((ChaosEvent(at_s=0.0, action="kill"),))
                eng.drain(timeout=300)
                out["wall_s"] = time.perf_counter() - t0
                outs = [r.result(timeout=10) for r in reqs]
                st = eng.stats()
                sup = s.stats()["supervisor"]
                out["respawns"] = len(sup["respawns"])
                out["completed"] = st["completed"]
                out["replica_deaths"] = st["replica_deaths"]
                out["recovered_requests"] = st["recovered_requests"]
                out["counts_exact"] = all(len(o) == gen for o in outs)
                out["victim_failed"] = victim.state.name != "RUNNING"
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    return out


def run(quick: bool = False):
    n_req = 10 if quick else 24
    gen = 16 if quick else 32
    plen = 8 if quick else 16
    batch = 2 if quick else 4
    max_len = 64 if quick else 128

    cfg = scaled_config("llama3_2_1b", "smoke")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
               for _ in range(n_req)]

    step_s = _step_seconds(model, params, batch, plen, max_len)
    # a request holds one of `batch` rows for ~gen steps; open-loop rate
    # at UTILIZATION of that capacity keeps the system loaded but stable
    rate = UTILIZATION * batch / (gen * step_s)
    arrivals = _arrivals(n_req, rate)

    base_outs, base_lat = _baseline(model, params, prompts, gen, arrivals,
                                    batch, max_len)
    sub_outs, sub_lat, st = _substrate(model, params, prompts, gen,
                                       arrivals, batch, max_len)

    base_p99, sub_p99 = _p99(base_lat), _p99(sub_lat)
    ratio = sub_p99 / base_p99 if base_p99 > 0 else float("inf")
    counts_exact = (all(len(o) == gen for o in base_outs)
                    and all(len(o) == gen for o in sub_outs)
                    and st["tokens_served"] == n_req * gen)
    dur = max(arrivals[-1], 1e-9)
    common.emit("bench_serving.baseline", base_p99,
                f"p99_s rate={rate:.1f}req/s n={n_req}")
    common.emit("bench_serving.substrate", sub_p99,
                f"p99_ratio={ratio:.2f} exact={counts_exact} "
                f"tok/s={st['tokens_served'] / dur:.0f}")
    common.record("bench_serving.substrate",
                  p99_s=sub_p99, baseline_p99_s=base_p99,
                  p99_ratio=ratio, max_p99_ratio=MAX_P99_RATIO,
                  completed=st["completed"], requests=n_req,
                  counts_exact=counts_exact, tokens=st["tokens_served"],
                  rate_hz=rate, batch=batch, gen=gen,
                  step_seconds=step_s, refills=st["refills"])

    storm = _chaos(model, params, prompts[:8 if quick else 12],
                   gen, batch, max_len)
    common.emit("bench_serving.chaos", storm["wall_s"],
                f"completed={storm['completed']} "
                f"respawns={storm['respawns']} "
                f"recovered={storm['recovered_requests']} "
                f"exact={storm['counts_exact']}")
    common.record("bench_serving.chaos",
                  seconds=storm["wall_s"], gen=gen, batch=batch,
                  requests=8 if quick else 12, **{
                      k: storm[k] for k in
                      ("completed", "respawns", "replica_deaths",
                       "recovered_requests", "counts_exact",
                       "victim_failed")})


def gate(records) -> None:
    """CI guardrails for serving on the substrate (raises SystemExit)."""
    import sys
    rows = {r["name"]: r for r in records}
    r = rows.get("bench_serving.substrate")
    if r is None:
        print("bench gate: no bench_serving.substrate record",
              file=sys.stderr)
        raise SystemExit(1)
    if r.get("completed") != r.get("requests"):
        print(f"bench gate: serving completed {r.get('completed')}/"
              f"{r.get('requests')} requests", file=sys.stderr)
        raise SystemExit(1)
    if not r.get("counts_exact"):
        print("bench gate: serving token counts not exact (padded or "
              "retired rows leaked into accounting)", file=sys.stderr)
        raise SystemExit(1)
    if r.get("p99_ratio", float("inf")) > r.get("max_p99_ratio",
                                                MAX_P99_RATIO):
        print(f"bench gate: substrate serving p99 "
              f"{r.get('p99_ratio'):.2f}x the isolated stack "
              f"(ceiling {MAX_P99_RATIO}x)", file=sys.stderr)
        raise SystemExit(1)
    c = rows.get("bench_serving.chaos")
    if c is None:
        print("bench gate: no bench_serving.chaos record", file=sys.stderr)
        raise SystemExit(1)
    if c.get("completed") != c.get("requests") or not c.get("counts_exact"):
        print(f"bench gate: chaos kill lost requests "
              f"({c.get('completed')}/{c.get('requests')} complete, "
              f"exact={c.get('counts_exact')})", file=sys.stderr)
        raise SystemExit(1)
    if c.get("respawns", 0) < 1 or c.get("replica_deaths", 0) < 1:
        print(f"bench gate: chaos kill not exercised "
              f"(respawns={c.get('respawns')} "
              f"deaths={c.get('replica_deaths')})", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
    gate(common.records())
