"""Pipelined map_reduce engine vs PR 1's sequential i+1 prefetch, and
GDSF vs LRU eviction on a skewed-size working set.

Scenario A (the tentpole's acceptance case): iterative KMeans whose working
set is 2x the device-tier budget, with the overflow spilling through a
1.5-partition host tier onto a (simulated, read-slow) disk tier — every
iteration is a sequential scan against LRU, the adversarial case where all
partitions restage each pass.  The sequential engine overlaps exactly one
stage-in with compute; the depth-k engine keeps `DEPTH` stage-ins in flight
on a `DEPTH`-worker stager and fuses the partial reduction, so the same
scan is bounded by staging-bandwidth/DEPTH instead of staging-latency.

Scenario B: 8 small-hot partitions + rotating large-cold scans against one
device budget.  LRU demotes the small hot set to the throttled disk the
moment a recently-touched scan partition needs room; GDSF (frequency x
restage-cost / size) evicts the large cold scan instead, so the hot set
never pays the disk.

Rows: bench_mapreduce.<variant>,us_per_run,derived; machine-readable rows
(wall seconds, bytes staged, evictions) land in the BENCH_*.json artifact
via benchmarks.common.record.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, record

ITERS = 3
DEPTH = 4          # pipeline depth = stager pool width


def _cold_profile(part_bytes: int, read_ms: float = 12.0,
                  write_ms: float = 0.3):
    """A disk whose reads cost ~read_ms per partition and writes ~write_ms
    (restage-dominated, so overlap is what the benchmark measures)."""
    from repro.core.memory import TierProfile
    return TierProfile("bench_cold_disk", simulate=True, latency=1e-3,
                       read_bw=part_bytes / (read_ms * 1e-3),
                       write_bw=part_bytes / (write_ms * 1e-3))


def _overbudget_setup(root: Path, pts, parts: int, policy: str = "lru"):
    """Fresh 2x-over-budget hierarchy: device holds half the partitions,
    host holds ~1.5, the rest sit on the simulated cold disk."""
    from repro.core import DataUnit, TierManager, make_backend
    from repro.core.memory import FileBackend

    part_bytes = pts.nbytes // parts
    tm = TierManager(
        {"file": FileBackend(root, _cold_profile(part_bytes)),
         "host": make_backend("host"),
         "device": make_backend("device")},
        {"device": (parts // 2) * part_bytes + part_bytes // 2,
         "host": part_bytes + part_bytes // 2},
        promote_threshold=0, max_workers=DEPTH, policy=policy)
    du = DataUnit.from_array("mr-bench", pts, parts, tm.backends,
                             tier="device", tier_manager=tm)
    return tm, du


def _staged(tm) -> dict:
    s = tm.event_summary()
    return {"bytes_staged": s["bytes_promoted"] + s["bytes_demoted"],
            "evictions": s["demotions"]}


def _bench_pipelined_vs_sequential(quick: bool, workdir: Path) -> float:
    from repro.core import kmeans, make_backend, make_blobs
    from repro.core.data import DataUnit

    n, parts = (8_000, 8) if quick else (32_000, 16)
    k = 8
    pts, _ = make_blobs(n, k, d=16, seed=0)

    # warm the jit cache so neither engine pays compile inside the timer
    warm = DataUnit.from_array(
        "warm", pts[: n // parts], 1,
        {"host": make_backend("host"), "device": make_backend("device")},
        tier="device")
    kmeans(warm, k=k, iters=1, seed=0)

    results = {}
    for mode, pipeline in (("sequential", False), ("pipelined", True)):
        tm, du = _overbudget_setup(workdir / mode, pts, parts)
        try:
            t0 = time.perf_counter()
            r = kmeans(du, k=k, iters=ITERS, seed=0, pipeline=pipeline,
                       prefetch_depth=DEPTH)
            wall = time.perf_counter() - t0
            tm.drain(timeout=60)
            assert np.isfinite(r.sse_history).all()
            results[mode] = (wall, _staged(tm), r.sse_history[-1])
        finally:
            tm.close()

    t_seq, staged_seq, sse_seq = results["sequential"]
    t_pipe, staged_pipe, sse_pipe = results["pipelined"]
    np.testing.assert_allclose(sse_pipe, sse_seq, rtol=1e-3)
    speedup = t_seq / max(t_pipe, 1e-9)
    emit("bench_mapreduce.sequential[sim]", t_seq, f"sse={sse_seq:.3e}")
    emit("bench_mapreduce.pipelined[sim]", t_pipe,
         f"speedup={speedup:.2f}x depth={DEPTH}")
    record("bench_mapreduce.sequential", seconds=t_seq, **staged_seq)
    record("bench_mapreduce.pipelined", seconds=t_pipe, speedup=speedup,
           depth=DEPTH, **staged_pipe)
    if speedup < 1.5:
        emit("bench_mapreduce.WARNING", 0.0,
             f"pipelined speedup {speedup:.2f}x below the 1.5x target")
    return speedup


def _bench_gdsf_vs_lru(quick: bool, workdir: Path) -> float:
    """Skewed-size working set: hot smalls + rotating large cold scans."""
    from repro.core import TierManager, make_backend
    from repro.core.memory import FileBackend

    small_kb = 8 if quick else 32
    small_bytes = small_kb * 1024
    large_bytes = 8 * small_bytes
    n_small, n_large = 8, 4
    rounds = 6
    budget = n_small * small_bytes + large_bytes + small_bytes // 2

    results = {}
    for policy in ("lru", "gdsf"):
        tm = TierManager(
            {"file": FileBackend(workdir / policy,
                                 _cold_profile(large_bytes)),
             "device": make_backend("device")},
            {"device": budget}, promote_threshold=0, policy=policy)
        try:
            for i in range(n_small):
                tm.put(f"hot{i}", np.zeros(small_bytes // 4, np.float32),
                       "device")
            for j in range(n_large):
                tm.put(f"scan{j}", np.zeros(large_bytes // 4, np.float32),
                       "file")
            t0 = time.perf_counter()
            for r in range(rounds):
                big = f"scan{r % n_large}"
                tm.stage(big, "device")
                tm.get(big)
                for _ in range(2):
                    for i in range(n_small):
                        tm.get(f"hot{i}")
                tm.get(big)     # the scan output is re-read last (MRU)
            wall = time.perf_counter() - t0
            results[policy] = (wall, _staged(tm))
        finally:
            tm.close()

    t_lru, staged_lru = results["lru"]
    t_gdsf, staged_gdsf = results["gdsf"]
    speedup = t_lru / max(t_gdsf, 1e-9)
    emit("bench_mapreduce.evict_lru[sim]", t_lru,
         f"evictions={staged_lru['evictions']}")
    emit("bench_mapreduce.evict_gdsf[sim]", t_gdsf,
         f"speedup_vs_lru={speedup:.2f}x "
         f"evictions={staged_gdsf['evictions']}")
    record("bench_mapreduce.evict_lru", seconds=t_lru, **staged_lru)
    record("bench_mapreduce.evict_gdsf", seconds=t_gdsf,
           speedup_vs_lru=speedup, **staged_gdsf)
    if speedup < 1.0:
        emit("bench_mapreduce.WARNING", 0.0,
             f"GDSF slower than LRU ({speedup:.2f}x)")
    return speedup


def run(quick: bool = False) -> None:
    root = Path(tempfile.mkdtemp(prefix="bench_mapreduce_"))
    try:
        _bench_pipelined_vs_sequential(quick, root)
        _bench_gdsf_vs_lru(quick, root)
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
