"""Measured train/decode step walltime for small presets on this host —
the CPU-side end-to-end throughput guard (TPU numbers live in §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.train import scaled_config
from repro.models.model import build_model
from repro.train import steps as steps_mod


def run():
    for arch in ("llama3_2_1b", "falcon_mamba_7b", "mixtral_8x22b"):
        cfg = scaled_config(arch, "smoke")
        model = build_model(cfg)
        pcfg, tcfg = ParallelConfig(), TrainConfig()
        step = jax.jit(steps_mod.make_train_step(model, pcfg, tcfg),
                       donate_argnums=(0,))
        state = steps_mod.init_train_state(model, jax.random.key(0), pcfg)
        b, s = 4, 64
        batch = {"tokens": jnp.zeros((b, s), jnp.int32),
                 "labels": jnp.zeros((b, s), jnp.int32)}

        state2 = state

        def call():
            nonlocal state2
            state2, m = step(state2, batch)
            jax.block_until_ready(m["loss"])

        t = timeit(call, repeats=3, warmup=2)
        emit(f"train_step/{arch}/smoke/b{b}s{s}", t,
             f"{b * s / t:.0f}tok/s(cpu)")


if __name__ == "__main__":
    run()
