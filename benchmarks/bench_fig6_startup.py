"""Paper Fig. 6: pilot/cluster startup overhead per backend substrate.

The paper measured Pilot-Data agent startup on Stampede/EC2 vs YARN/Mesos
application startup (YARN slowest: two-stage AM+container allocation) and
YARN/Spark cluster spawn-on-HPC via Pilot-Hadoop. Here each simulated
substrate carries the corresponding provisioning-latency model (ratios from
the paper; absolute values scaled 100x down to keep benches fast — marked
SIMULATED), plus the real in-process backend as the zero-overhead floor.
Derived column: provision seconds.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import PilotComputeDescription, PilotComputeService
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import SUBSTRATES, SimulatedClusterBackend


def run():
    svc = PilotComputeService()
    for substrate in SUBSTRATES:
        register_backend(SimulatedClusterBackend(substrate=substrate,
                                                 use_devices=False))
        for n in (8, 64):
            pilot = svc.submit_pilot(PilotComputeDescription(
                backend="simulated", num_devices=n))
            emit(f"fig6_startup/{substrate}/n{n}", pilot.provision_time,
                 f"{pilot.provision_time:.3f}s(SIMULATED)")
            svc.release(pilot)
    pilot = svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
    emit("fig6_startup/inprocess/n1", pilot.provision_time,
         f"{pilot.provision_time:.4f}s")

    # the paper's deeper claim: retained pilots amortize startup — the first
    # CU pays compile ("JVM startup" analogue), subsequent CUs are warm
    import time

    import jax
    import jax.numpy as jnp
    from repro.core import ComputeDataManager

    manager = ComputeDataManager(svc)
    x = jnp.ones((256, 256))
    fn = pilot.jit_cached("f6", lambda: jax.jit(lambda a: (a @ a).sum()))
    for label in ("cold", "warm"):
        t0 = time.perf_counter()
        cu = manager.run(lambda: jax.block_until_ready(fn(x)))
        cu.result()
        emit(f"fig6_cu_latency/{label}", time.perf_counter() - t0,
             "retained-executable amortization")
    svc.release(pilot)
    svc.cancel_all()


if __name__ == "__main__":
    run()
