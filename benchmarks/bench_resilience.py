"""PR 7 resilience bench: chaos-kill one of N pilots mid-KMeans.

The self-healing contract, measured end-to-end on the acceptance
workload (the paper's §4.3 KMeans over a replicated points DataUnit):

  * ``fault_free`` — 3 pilots, replication target 2, no chaos: the
    baseline wall clock;
  * ``chaos_kill`` — same fleet + a supervised session, one pilot killed
    (volatile tiers wiped) mid-run by a ChaosPolicy schedule.  The
    supervisor must detect the death, respawn a replacement from the
    dead pilot's own description, and the repair worker must restore the
    declared replication target — while map_reduce's task-level retries
    keep the KMeans converging.

The gate asserts: ZERO data loss (every partition byte-identical to the
source after the storm), replication restored to target on every
partition, at least one recorded respawn, and chaos wall time within
``MAX_SLOWDOWN``x of fault-free.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import PilotSession, make_blobs
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import (ChaosEvent, ChaosPolicy,
                                           FaultPolicy,
                                           SimulatedClusterBackend)

MAX_SLOWDOWN = 1.5          # chaos run vs fault-free wall time
REPLICATION = 2


def _fleet(s: PilotSession, mem_gb: float):
    victim = s.add_pilot(backend="simulated", startup_seconds=0.01,
                         memory_gb=mem_gb, host_memory_gb=4 * mem_gb)
    others = s.add_pilots(2, memory_gb=mem_gb, host_memory_gb=4 * mem_gb)
    return victim, others


def _kmeans_storm(pts, parts: int, iters: int, chaos: bool,
                  kill_at_s: float, tag: str):
    """One supervised KMeans run; with chaos=True the first simulated
    pilot is killed (memory wiped) mid-run."""
    policy = (ChaosPolicy(lose_memory=True, target_index=0,
                          events=(ChaosEvent(at_s=kill_at_s,
                                             action="kill"),))
              if chaos else FaultPolicy())
    register_backend(SimulatedClusterBackend(substrate="slurm",
                                             policy=policy))
    mem_gb = max(0.02, 4.0 * pts.nbytes / 2 ** 30)
    out = {}
    ckdir = tempfile.mkdtemp(prefix=f"bench-resilience-{tag}-")
    with PilotSession(name=f"bench-resilience-{tag}", supervise=True,
                      checkpoint_dir=ckdir,
                      supervisor_kwargs={"interval_s": 0.02,
                                         "min_heartbeat_s": 0.05,
                                         "repair_interval_s": 0.05}) as s:
        victim, _ = _fleet(s, mem_gb)
        du = s.data("pts", pts, parts=parts, persist=True,
                    replication=REPLICATION)
        s.data_service.replicate_to_pilot(du, victim.id, tier="host")
        t0 = time.perf_counter()
        res = s.kmeans(du, k=8, iters=iters)
        out["kmeans_s"] = time.perf_counter() - t0
        out["sse"] = res.sse_history[-1]
        # let detection + respawn + repair drain (bounded)
        deadline = time.monotonic() + 30.0
        while chaos and time.monotonic() < deadline:
            rs = s.data_service.replication_stats()["pts"]
            if s.supervisor.respawns and rs["under"] == 0:
                break
            time.sleep(0.05)
        out["wall_s"] = time.perf_counter() - t0
        sup = s.stats()["supervisor"]
        rs = s.data_service.replication_stats()["pts"]
        out["respawns"] = len(sup["respawns"])
        out["repairs"] = s.data_service.counters["repairs"]
        out["under_replicated"] = rs["under"]
        out["min_replicas"] = min(rs["per_partition"].values())
        # zero-data-loss audit: every partition byte-identical to source
        ref = np.array_split(pts, parts, axis=0)
        out["data_intact"] = all(
            np.array_equal(np.asarray(du.partition(i)), ref[i])
            for i in range(parts))
    shutil.rmtree(ckdir, ignore_errors=True)
    return out


def run(quick: bool = False):
    n = 400_000 if quick else 1_200_000
    parts = 12 if quick else 16
    iters = 5 if quick else 8
    pts, _ = make_blobs(n, 8, d=8, seed=0)

    # warmup: pay the jit compilation outside the timed comparison
    _kmeans_storm(pts, parts, 1, chaos=False, kill_at_s=1e9, tag="warmup")
    base = _kmeans_storm(pts, parts, iters, chaos=False, kill_at_s=1e9,
                         tag="fault-free")
    # kill lands mid-run: after the first iteration is underway
    kill_at = max(0.02, 0.3 * base["kmeans_s"])
    storm = _kmeans_storm(pts, parts, iters, chaos=True,
                          kill_at_s=kill_at, tag="chaos")

    slowdown = (storm["kmeans_s"] / base["kmeans_s"]
                if base["kmeans_s"] > 0 else float("inf"))
    common.emit("bench_resilience.fault_free", base["kmeans_s"],
                f"parts={parts} iters={iters}")
    common.emit("bench_resilience.chaos_kill", storm["kmeans_s"],
                f"slowdown={slowdown:.2f}x respawns={storm['respawns']} "
                f"repairs={storm['repairs']} "
                f"intact={storm['data_intact']}")
    common.record("bench_resilience.chaos_kill",
                  seconds=storm["kmeans_s"],
                  fault_free_seconds=base["kmeans_s"],
                  slowdown_vs_fault_free=slowdown,
                  max_slowdown=MAX_SLOWDOWN,
                  respawns=storm["respawns"],
                  repairs=storm["repairs"],
                  under_replicated=storm["under_replicated"],
                  min_replicas=storm["min_replicas"],
                  replication_target=REPLICATION,
                  data_intact=storm["data_intact"],
                  sse=storm["sse"], parts=parts, iters=iters, n=n)


def gate(records) -> None:
    """CI guardrails for the self-healing path (raises SystemExit)."""
    import sys
    rows = {r["name"]: r for r in records}
    r = rows.get("bench_resilience.chaos_kill")
    if r is None:
        print("bench gate: no bench_resilience.chaos_kill record",
              file=sys.stderr)
        raise SystemExit(1)
    if not r.get("data_intact"):
        print("bench gate: chaos kill LOST DATA (partition mismatch "
              "after recovery)", file=sys.stderr)
        raise SystemExit(1)
    if r.get("respawns", 0) < 1:
        print("bench gate: chaos kill produced no respawn", file=sys.stderr)
        raise SystemExit(1)
    if (r.get("under_replicated", 1) != 0
            or r.get("min_replicas", 0) < r.get("replication_target", 2)):
        print(f"bench gate: replication not restored "
              f"(under={r.get('under_replicated')} "
              f"min={r.get('min_replicas')} "
              f"target={r.get('replication_target')})", file=sys.stderr)
        raise SystemExit(1)
    if r.get("slowdown_vs_fault_free", float("inf")) > MAX_SLOWDOWN:
        print(f"bench gate: chaos run "
              f"{r.get('slowdown_vs_fault_free'):.2f}x fault-free wall "
              f"time (ceiling {MAX_SLOWDOWN}x)", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
    gate(common.records())
