"""Paper Fig. 9 (+ the 212x claim): KMeans across Pilot-Data backends.

Paper scenarios (points x clusters): (i) 1M x 50, (ii) 100k x 500,
(iii) 10k x 5000 — constant compute, growing shuffle. Backends:
  file@stampede-disk (SIMULATED bandwidth)  ~ paper's Pilot-Data/File
  host                                       ~ paper's Redis backend
  device (HBM-resident, jitted map)          ~ paper's Spark backend
Derived: per-iteration seconds + speedup vs the file backend. The paper's
headline is the *ratio structure* (memory >> file, device best); exact 212x
depends on their cluster's disk:mem gap.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import (ComputeDataManager, DataUnit, PilotComputeDescription,
                        PilotComputeService, kmeans, make_backend, make_blobs)
from repro.core.memory import PROFILES, FileBackend

# the paper's exact scenario sizes
SCENARIOS = {"i": (1_000_000, 50), "ii": (100_000, 500), "iii": (10_000, 5_000)}
DIM = 8
ITERS = 3


def run(tmp_root: str = "/tmp/repro_bench_fig9"):
    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(svc)
    for name, (n, k) in SCENARIOS.items():
        pts, _ = make_blobs(n, min(k, 256), d=DIM, seed=3)
        backends = {
            "file": FileBackend(f"{tmp_root}/{name}",
                                PROFILES["stampede_disk"]),
            "host": make_backend("host"),
            "device": make_backend("device"),
        }
        base_t = None
        io_file = pts.nbytes / PROFILES["stampede_disk"].read_bw
        for tier in ("file", "host", "device"):
            du = DataUnit.from_array(f"km-{name}-{tier}", pts, 4, backends,
                                     tier=tier)
            res = kmeans(du, k=k, iters=ITERS,
                         manager=None if tier == "device" else manager,
                         pilot=pilot if tier == "device" else None)
            per_iter = float(np.mean(res.iter_seconds[1:])
                             if len(res.iter_seconds) > 1
                             else res.iter_seconds[0])
            if tier == "file":
                base_t = per_iter
            # on-TPU projection: compute shrinks to roofline (~0), staging
            # stays -> the paper's memory-vs-file gap is the io ratio
            comp = max(per_iter - (io_file if tier == "file" else 0.0), 1e-4)
            proj = (io_file + comp * 0.01) / (comp * 0.01) if tier != "file" else 1.0
            emit(f"fig9_kmeans/{name}/{tier}", per_iter,
                 f"speedup_vs_file={base_t / per_iter:.1f}x "
                 f"sse={res.sse_history[-1]:.0f} io_s={io_file if tier=='file' else 0:.2f} "
                 f"tpu_projected={proj:.0f}x")
            du.delete()
    svc.cancel_all()


if __name__ == "__main__":
    run()
