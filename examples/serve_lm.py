"""LM serving ON the pilot substrate: tiered shards + KV pages, replica
routing, continuous batching with refill, and mid-stream recovery.

    PYTHONPATH=src python examples/serve_lm.py [--arch yi_9b] [--pilots 2]

The model's parameter shards and each request's KV-page trail live as
tiered Pilot-Data partitions; every pilot runs its decode loop as a
long-lived resident task; requests route to replicas through the
session's SchedulingPolicy.  Run with ``--supervise`` and a checkpoint
dir to make a mid-stream pilot kill recoverable (see
tests/test_serving.py for that path under test).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--pilots", default="2")
    args = ap.parse_args()
    stats = serve_main(["--arch", args.arch, "--preset", args.preset,
                        "--requests", "16", "--batch", "4",
                        "--prompt-len", "16", "--gen", "32",
                        "--max-len", "128", "--pilots", args.pilots])
    assert stats["completed"] == 16 and stats["tokens_served"] == 16 * 32


if __name__ == "__main__":
    main()
