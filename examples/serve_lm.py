"""Batched serving with continuous batching on a pilot-retained mesh.

    PYTHONPATH=src python examples/serve_lm.py [--arch yi_9b]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--preset", default="smoke")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--preset", args.preset,
                "--requests", "16", "--batch", "4", "--prompt-len", "16",
                "--gen", "32", "--max-len", "128"])


if __name__ == "__main__":
    main()
