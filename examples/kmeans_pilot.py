"""The paper's §4.3 experiment: KMeans over Pilot-Data Memory backends.

    PYTHONPATH=src python examples/kmeans_pilot.py [--scenario i|ii|iii]

Runs Lloyd's KMeans with the points DataUnit held in each storage tier:
file (throttled to the paper's Stampede-disk profile — SIMULATED), host
(the Redis analogue) and device/HBM (the Spark analogue), and reports the
per-iteration times + speedups. See benchmarks/bench_fig9_kmeans.py for the
full Fig. 9 sweep.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (ComputeDataManager, DataUnit, PilotComputeDescription,
                        PilotComputeService, kmeans, make_backend, make_blobs)
from repro.core.analytics import PAPER_SCENARIOS
from repro.core.memory import PROFILES, FileBackend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="ii", choices=list(PAPER_SCENARIOS))
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--dim", type=int, default=8)
    args = ap.parse_args()
    n, k = PAPER_SCENARIOS[args.scenario]
    print(f"scenario ({args.scenario}): {n} points x {k} clusters")
    pts, _ = make_blobs(n, min(k, 256), d=args.dim)

    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(svc)
    backends = {"file": FileBackend("/tmp/kmeans_pilot",
                                    PROFILES["stampede_disk"]),
                "host": make_backend("host"),
                "device": make_backend("device")}
    base = None
    for tier in ("file", "host", "device"):
        du = DataUnit.from_array(f"pts-{tier}", pts, 4, backends, tier=tier)
        res = kmeans(du, k=k, iters=args.iters,
                     manager=None if tier == "device" else manager,
                     pilot=pilot if tier == "device" else None)
        per = float(np.mean(res.iter_seconds))
        base = base or per
        print(f"  tier={tier:7s} {per*1e3:8.1f} ms/iter  "
              f"speedup={base/per:5.2f}x  sse={res.sse_history[-1]:.0f}")
        du.delete()
    svc.cancel_all()


if __name__ == "__main__":
    main()
