"""End-to-end LM training through the full stack (e2e driver).

    PYTHONPATH=src python examples/train_lm.py                 # quick demo
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
        # the ~100M-param / few-hundred-steps configuration (sized for a
        # real accelerator; the demo default keeps CPU walltime sane)

Pilot-managed mesh -> file-tier corpus -> host staging -> jitted train_step
with FSDP/TP sharding rules -> async checkpoints. Every assigned arch works
via --arch (smoke-scaled variants of its family).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--preset", args.preset,
                "--steps", str(args.steps), "--batch", str(args.batch),
                "--seq", str(args.seq), "--lr", "1e-2",
                "--ckpt-dir", "/tmp/train_lm_example",
                "--log-every", "20"])


if __name__ == "__main__":
    main()
