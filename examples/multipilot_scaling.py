"""Scaling out with multi-pilot distributed Pilot-Data (Pilot-API v2).

Two pilots each own a private TierManager (their retained memory ask);
the session's PilotDataService tracks which pilot holds which partition,
and an InterconnectModel prices cross-pilot transfers: when one pilot
needs a partition a sibling already holds, the fetch path reads it over
the modelled fabric link instead of re-pulling from the home store —
and a write still invalidates every replica coherently.

    PYTHONPATH=src python examples/multipilot_scaling.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import InterconnectModel, PilotSession, make_blobs


def main():
    pts, _ = make_blobs(8_000, 8, d=16, seed=0)

    # the fabric: 12.5 GB/s default pilot-to-pilot links, a much slower
    # modelled home re-pull — so sibling replicas win the fetch race
    with PilotSession(interconnect=InterconnectModel()) as s:
        pilots = s.add_pilots(2, memory_gb=0.05)

        # home placement: shared (cluster) storage the pilots pull from
        du = s.data("points", pts, parts=8)

        # distribute the working set: half the partitions to each pilot
        du.replicate_to_pilot(pilots[0], parts=range(0, 4))
        du.replicate_to_pilot(pilots[1], parts=range(4, 8))
        for p in pilots:
            print(f"{p.id}: replica residency {du.replica_residency(p)}")

        # replica-aware map_reduce: each pilot's group reads its own tiers
        r = s.kmeans(du, k=8, iters=3)
        sched = s.manager.stats()
        print(f"kmeans sse={r.sse_history[-1]:.3e} "
              f"({sched['submitted']} CUs over "
              f"{len(sched['per_pilot'])} pilots)")

        # cross-pilot replica read: pilot 1 pulls a partition only pilot 0
        # holds — the cost model routes it over the fabric, not home
        before = s.data_service.counters["sibling_reads"]
        du.partition(0, pilot=pilots[1])
        print(f"sibling reads over the modelled interconnect: "
              f"{s.data_service.counters['sibling_reads'] - before}")

        # coherent write: replicas are invalidated, readers re-pull
        du.update_partition(0, np.zeros_like(np.asarray(du.partition(0))))
        print(f"after write: partition 0 holders = "
              f"{s.data_service.holders(du._key(0))} "
              f"(re-pulled on next read)")
        np.testing.assert_array_equal(
            du.partition(0, pilot=pilots[0]),
            np.zeros_like(np.asarray(du.partition(0))))
        print("replica read after invalidation is coherent")

        # the zero-copy plane metered every one of those reads: views are
        # free aliases, copies are the memcpys the plane could not elide
        t = s.stats()["transport"]
        print(f"transport: {t['bytes_viewed'] / 2**20:.1f} MiB viewed "
              f"({t['views']} views) vs "
              f"{t['bytes_copied'] / 2**20:.1f} MiB copied "
              f"({t['copies']} copies), codec calls={t['codec']}")


if __name__ == "__main__":
    main()
