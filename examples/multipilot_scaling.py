"""Scaling out with multi-pilot distributed Pilot-Data.

Two pilots each own a private TierManager (their retained memory ask); a
PilotDataService tracks which pilot holds which partition.  The working
set is replicated half-and-half, so the replica-aware scheduler routes
each map_reduce group to the pilot already holding its data, each pilot
reads through its OWN tiers, and a write invalidates every replica
coherently.

    PYTHONPATH=src python examples/multipilot_scaling.py
"""
import numpy as np

from repro.core import (ComputeDataManager, DataUnit,
                        PilotComputeDescription, PilotComputeService,
                        PilotDataService, kmeans, make_backend, make_blobs)


def main():
    svc = PilotComputeService()
    pds = PilotDataService()
    manager = ComputeDataManager(svc)
    try:
        # two pilots, each with its own managed memory (device budget =
        # the memory_gb ask), both joined to the data service
        pilots = [svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", memory_gb=0.05)) for _ in range(2)]
        for p in pilots:
            pds.register_pilot(p)

        # the home placement: shared (cluster) storage the pilots pull from
        pts, _ = make_blobs(8_000, 8, d=16, seed=0)
        du = pds.register(DataUnit.from_array(
            "points", pts, 8, {"host": make_backend("host")}, tier="host"))

        # distribute the working set: half the partitions to each pilot
        du.replicate_to_pilot(pilots[0], parts=range(0, 4))
        du.replicate_to_pilot(pilots[1], parts=range(4, 8))
        for p in pilots:
            print(f"{p.id}: replica residency {du.replica_residency(p)}")

        # replica-aware map_reduce: each pilot's group reads its own tiers
        r = kmeans(du, k=8, iters=3, manager=manager)
        print(f"kmeans sse={r.sse_history[-1]:.3e} "
              f"({len(manager.history)} CUs, "
              f"pilots used: {sorted({h['pilot'] for h in manager.history})})")

        # coherent write: replicas are invalidated, readers re-pull
        du.update_partition(0, np.zeros_like(np.asarray(du.partition(0))))
        print(f"after write: partition 0 holders = "
              f"{pds.holders(du._key(0))} (re-pulled on next read)")
        np.testing.assert_array_equal(
            du.partition(0, pilot=pilots[0]),
            np.zeros_like(np.asarray(du.partition(0))))
        print("replica read after invalidation is coherent")
    finally:
        pds.close()
        svc.cancel_all()


if __name__ == "__main__":
    main()
