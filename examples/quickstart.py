"""Quickstart: the Pilot-API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Provisions a pilot (retained device allocation), stages a DataUnit through
the storage tiers, runs Compute-Units through the data-aware scheduler, and
finishes with a map_reduce over the in-memory tier.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (ComputeDataManager, DataUnit, PilotComputeDescription,
                        PilotComputeService, make_backend, map_reduce)


def main():
    # 1. provision a Pilot-Compute (placeholder allocation; CUs multiplex on it)
    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription(
        backend="inprocess", num_devices=1, affinity="demo"))
    manager = ComputeDataManager(svc)
    print(f"pilot up: {pilot} (provisioned in {pilot.provision_time:.3f}s)")

    # 2. a Compute-Unit is just a function + late binding
    cu = manager.run(lambda a, b: a @ b,
                     np.eye(4, dtype=np.float32), np.arange(16.0).reshape(4, 4))
    print("CU result trace:", np.asarray(cu.result()).trace())

    # 3. Data-Units: one API over file / host / device(HBM) tiers
    backends = {"file": make_backend("file", root="/tmp/quickstart_du"),
                "host": make_backend("host"),
                "device": make_backend("device")}
    data = np.random.default_rng(0).normal(size=(8192, 16)).astype(np.float32)
    du = DataUnit.from_array("matrix", data, num_partitions=4,
                             backends=backends, tier="file")
    du.to_tier("device")  # stage file -> HBM (Pilot-Data Memory)
    print(f"staged {du} via {[t['to'] for t in du.transfer_log]}")

    # 4. MapReduce over the in-memory DU (no restaging between iterations)
    total = map_reduce(du, lambda p: jnp.sum(p * p), lambda a, b: a + b,
                       pilot=pilot)
    print(f"sum of squares via map_reduce: {float(total):.1f} "
          f"(numpy check: {float((data * data).sum()):.1f})")

    svc.cancel_all()
    print("quickstart OK")


if __name__ == "__main__":
    main()
