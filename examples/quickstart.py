"""Quickstart: the Pilot-API v2 in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

One PilotSession owns the whole stack — pilots (retained device
allocations), Data-Units (tiered, replica-managed), the data-aware
scheduler, and deterministic teardown.  The v1 objects it composes
(PilotComputeService / ComputeDataManager / PilotDataService) remain
public; see examples/kmeans_pilot.py for the legacy surface.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import PilotSession


def main():
    data = np.random.default_rng(0).normal(size=(8192, 16)).astype(np.float32)

    with PilotSession() as s:
        # 1. provision a Pilot-Compute with a retained-memory ask (its own
        #    managed device/host tier hierarchy)
        pilot = s.add_pilot(num_devices=1, memory_gb=0.05, affinity="demo")
        print(f"pilot up: {pilot} (provisioned in "
              f"{pilot.provision_time:.3f}s)")

        # 2. a Compute-Unit is just a function + late binding
        cu = s.run(lambda a, b: a @ b, np.eye(4, dtype=np.float32),
                   np.arange(16.0).reshape(4, 4))
        print("CU result trace:", np.asarray(cu.result()).trace())

        # 3. a Data-Unit: partitioned, session-bound, replica-managed
        du = s.data("matrix", data, parts=4)
        du.replicate_to_pilot(pilot)    # stage the working set into HBM
        print(f"staged {du}: replica residency "
              f"{du.replica_residency(pilot)}")

        # 4. MapReduce through the replica-aware pipelined engine
        total = s.map_reduce(du, lambda p: jnp.sum(p * p),
                             lambda a, b: a + b)
        print(f"sum of squares via map_reduce: {float(total):.1f} "
              f"(numpy check: {float((data * data).sum()):.1f})")

        print("scheduler:", s.stats()["scheduler"])
    # <- session teardown: replication drained, checkpoints flushed,
    #    TierManagers closed, pilots released
    print("quickstart OK")


if __name__ == "__main__":
    main()
