"""Self-healing session demo: a pilot is chaos-killed mid-KMeans and the
supervision layer recovers it live — detection, quarantine, respawn from
the dead pilot's own description, and replication repair — while the
analytics keep converging.  The recovery trace is printed straight from
``session.stats()["supervisor"]`` (the observability surface), so what
you see is what any dashboard would see.

    PYTHONPATH=src python examples/elastic_failover.py

Act 2 runs the step-loop path (``ResilientRunner``), which since PR 7
delegates its replace/quarantine mechanics to the same supervisor.
"""
import sys
import tempfile
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import (PilotComputeDescription, PilotComputeService,
                        PilotSession, make_blobs)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import (ChaosEvent, ChaosPolicy,
                                           FaultPolicy,
                                           SimulatedClusterBackend)
from repro.runtime.fault_tolerance import ResilientRunner


def trace_loop(session, stop, lines):
    """Poll the supervisor observability surface and narrate changes."""
    seen_q, seen_r = set(), 0
    while not stop.is_set():
        sup = session.stats().get("supervisor")
        if sup:
            for pid in sup["quarantined"]:
                if pid not in seen_q:
                    seen_q.add(pid)
                    phi = sup["pilots"].get(pid, {}).get("phi", float("inf"))
                    lines.append(f"  [trace] QUARANTINE {pid} "
                                 f"(phi={phi:.1f})")
            for ev in sup["respawns"][seen_r:]:
                seen_r += 1
                lines.append(f"  [trace] RESPAWN {ev['old_pilot']} -> "
                             f"{ev['new_pilot'] or '<aborted>'} "
                             f"({ev['reason']}, "
                             f"downtime {ev['downtime_s']*1e3:.0f}ms)")
        stop.wait(0.02)


def act1_supervised_session():
    print("== act 1: supervised PilotSession, chaos kill mid-KMeans ==")
    register_backend(SimulatedClusterBackend(
        substrate="slurm",
        policy=ChaosPolicy(lose_memory=True, target_index=0,
                           events=(ChaosEvent(at_s=0.15, action="kill"),))))
    pts, _ = make_blobs(200_000, 8, d=8, seed=0)
    with tempfile.TemporaryDirectory() as ck, \
         PilotSession(name="failover", supervise=True, checkpoint_dir=ck,
                      supervisor_kwargs={"interval_s": 0.02,
                                         "min_heartbeat_s": 0.05,
                                         "repair_interval_s": 0.05}) as s:
        victim = s.add_pilot(backend="simulated", startup_seconds=0.01,
                             memory_gb=0.1, host_memory_gb=0.4)
        s.add_pilots(2, memory_gb=0.1, host_memory_gb=0.4)
        du = s.data("pts", pts, parts=12, persist=True, replication=2)
        s.data_service.replicate_to_pilot(du, victim.id, tier="host")
        print(f"  fleet: {[p.id for p in s.pilots]}, victim {victim.id}")

        stop, lines = threading.Event(), []
        t = threading.Thread(target=trace_loop, args=(s, stop, lines))
        t.start()
        res = s.kmeans(du, k=8, iters=6)
        # wait for the repair queue to drain before auditing
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline:
            rs = s.data_service.replication_stats()["pts"]
            if s.supervisor.respawns and rs["under"] == 0:
                break
            time.sleep(0.05)
        stop.set()
        t.join()
        for ln in lines:
            print(ln)

        sup = s.stats()["supervisor"]
        rs = sup["replication"]["pts"]
        print(f"  kmeans SSE: {res.sse_history[-1]:.1f} "
              f"({len(res.sse_history)} iters)")
        print(f"  respawns: {len(sup['respawns'])}, "
              f"repairs: {s.data_service.counters['repairs']}, "
              f"replication under target: {rs['under']}")
        ref = np.array_split(pts, 12, axis=0)
        intact = all(np.array_equal(np.asarray(du.partition(i)), ref[i])
                     for i in range(12))
        print(f"  data intact after storm: {intact}")
        assert intact and len(sup["respawns"]) >= 1 and rs["under"] == 0


def act2_resilient_runner():
    print("== act 2: step-loop recovery (ResilientRunner on the same "
          "supervisor) ==")
    register_backend(SimulatedClusterBackend(
        substrate="yarn", policy=FaultPolicy(fail_devices_at=6)))
    svc = PilotComputeService()
    ckpt = CheckpointManager("/tmp/elastic_failover_ckpt", keep=2)
    runner = ResilientRunner(
        svc, PilotComputeDescription(backend="simulated"),
        ckpt, checkpoint_every=3, max_recoveries=5)

    def step_fn(state, batch):
        new = {"w": state["w"] + batch, "step": state["step"] + 1}
        return new, {"w": float(new["w"])}

    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    final, _ = runner.run(state, step_fn, num_steps=20,
                          batch_fn=lambda i: jnp.float32(1.0))
    print(f"  finished: w={float(final['w'])} (expected 20.0)")
    for ev in runner.recoveries:
        print(f"  recovery: pilot {ev.old_pilot} -> {ev.new_pilot}, "
              f"rolled back step {ev.step} -> {ev.restored_step}, "
              f"downtime {ev.downtime_s*1e3:.0f}ms")
    assert float(final["w"]) == 20.0
    svc.cancel_all()


def main():
    act1_supervised_session()
    act2_resilient_runner()
    print("elastic failover OK")


if __name__ == "__main__":
    main()
