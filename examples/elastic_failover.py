"""Fault-tolerance demo: pilot dies mid-training, the runner re-provisions,
restores the last checkpoint and finishes — zero manual intervention.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import PilotComputeDescription, PilotComputeService
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import FaultPolicy, SimulatedClusterBackend
from repro.runtime.fault_tolerance import ResilientRunner


def main():
    # a simulated YARN-ish substrate whose pilot dies after 6 CUs
    register_backend(SimulatedClusterBackend(
        substrate="yarn", policy=FaultPolicy(fail_devices_at=6)))
    svc = PilotComputeService()
    ckpt = CheckpointManager("/tmp/elastic_failover_ckpt", keep=2)
    runner = ResilientRunner(
        svc, PilotComputeDescription(backend="simulated"),
        ckpt, checkpoint_every=3, max_recoveries=5)

    def step_fn(state, batch):
        new = {"w": state["w"] + batch, "step": state["step"] + 1}
        return new, {"w": float(new["w"])}

    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    final, metrics = runner.run(state, step_fn, num_steps=20,
                                batch_fn=lambda i: jnp.float32(1.0))
    print(f"finished: w={float(final['w'])} (expected 20.0)")
    for ev in runner.recoveries:
        print(f"  recovery: pilot {ev.old_pilot} -> {ev.new_pilot}, "
              f"rolled back step {ev.step} -> {ev.restored_step}, "
              f"downtime {ev.downtime_s*1e3:.0f}ms")
    assert float(final["w"]) == 20.0
    svc.cancel_all()
    print("elastic failover OK")


if __name__ == "__main__":
    main()
