"""Decoder / enc-dec / hybrid transformer assembly for all 10 architectures.

One layer definition parameterized by (attention kind, ffn kind, parallel-SSM
flag); uniform stacks run under jax.lax.scan with remat (compact HLO, O(1)
compile in depth), heterogeneous stacks (hymba's per-layer global/SWA mix,
DeepSeek-V3's dense->MoE split) unroll or split into homogeneous sub-stacks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamSpec, dense_ffn, rms_norm, stack_specs)
from repro.parallel.sharding import with_logical_constraint

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def dense_ffn_specs(cfg: ModelConfig, d_ff: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    specs = {
        "w_up": ParamSpec((d, d_ff), ("embed", "mlp"), "scaled"),
        "w_down": ParamSpec((d_ff, d), ("mlp", "embed"), "scaled"),
    }
    if cfg.ffn_act == "swiglu":
        specs["w_gate"] = ParamSpec((d, d_ff), ("embed", "mlp"), "scaled")
    return specs


def layer_specs(cfg: ModelConfig, ffn: str = "dense",
                d_ff: Optional[int] = None) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {"norm1": ParamSpec((d,), ("embed",), "ones")}
    if cfg.attention == "gqa":
        specs["attn"] = attn.gqa_specs(cfg)
    elif cfg.attention == "mla":
        specs["attn"] = attn.mla_specs(cfg)
    if cfg.ssm is not None:
        specs["ssm"] = ssm_mod.ssm_specs(cfg)
        if cfg.parallel_ssm:
            specs["ssm_norm"] = ParamSpec((d,), ("embed",), "ones")
            specs["attn_norm"] = ParamSpec((d,), ("embed",), "ones")
    if ffn == "dense" and (d_ff or cfg.d_ff):
        specs["norm2"] = ParamSpec((d,), ("embed",), "ones")
        specs["ffn"] = dense_ffn_specs(cfg, d_ff or cfg.d_ff)
    elif ffn == "moe":
        specs["norm2"] = ParamSpec((d,), ("embed",), "ones")
        specs["moe"] = moe_mod.moe_specs(cfg)
    return specs


def encoder_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "norm1": ParamSpec((d,), ("embed",), "ones"),
        "attn": attn.gqa_specs(cfg),
        "norm2": ParamSpec((d,), ("embed",), "ones"),
        "ffn": dense_ffn_specs(cfg, cfg.d_ff),
    }


def decoder_xattn_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs = layer_specs(cfg, ffn="dense")
    specs["norm_x"] = ParamSpec((cfg.d_model,), ("embed",), "ones")
    specs["xattn"] = attn.gqa_specs(cfg)
    return specs


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), "normal", scale=0.02),
        "final_norm": ParamSpec((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), "scaled")

    if cfg.encoder_layers:  # enc-dec (whisper)
        specs["enc_layers"] = stack_specs(encoder_layer_specs(cfg), cfg.encoder_layers)
        specs["enc_norm"] = ParamSpec((d,), ("embed",), "ones")
        specs["layers"] = stack_specs(decoder_xattn_layer_specs(cfg), cfg.num_layers)
        return specs

    if cfg.vision_tokens:  # vlm projector (stubbed ViT -> LM)
        dv = cfg.vision_embed_dim
        specs["proj1"] = ParamSpec((dv, d), (None, "embed"), "scaled")
        specs["proj2"] = ParamSpec((d, d), ("embed", None), "scaled")

    if cfg.is_moe and cfg.moe.first_k_dense:
        dense_ff = cfg.moe.first_dense_d_ff or cfg.d_ff
        specs["layers_dense"] = stack_specs(
            layer_specs(cfg, ffn="dense", d_ff=dense_ff), cfg.moe.first_k_dense)
        specs["layers"] = stack_specs(
            layer_specs(cfg, ffn="moe"), cfg.num_layers - cfg.moe.first_k_dense)
    elif cfg.is_moe:
        specs["layers"] = stack_specs(layer_specs(cfg, ffn="moe"), cfg.num_layers)
    else:
        # hybrids scan too: per-layer window is scanned *data* (see
        # decoder_forward), so heterogeneous SWA/global mixes stay compact
        specs["layers"] = stack_specs(layer_specs(cfg, ffn="dense"), cfg.num_layers)

    if cfg.mtp_depth:  # DeepSeek-V3 multi-token prediction module
        dense_ff = (cfg.moe.first_dense_d_ff if cfg.is_moe else 0) or cfg.d_ff
        specs["mtp"] = {
            "norm_h": ParamSpec((d,), ("embed",), "ones"),
            "norm_e": ParamSpec((d,), ("embed",), "ones"),
            "proj": ParamSpec((2 * d, d), (None, "embed"), "scaled"),
            "layer": layer_specs(cfg, ffn="dense", d_ff=dense_ff),
            "final_norm": ParamSpec((d,), ("embed",), "ones"),
        }
    return specs


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _layer_window(cfg: ModelConfig, layer_idx: Optional[int]) -> int:
    if layer_idx is not None and layer_idx in cfg.global_attn_layers:
        return 0
    return cfg.sliding_window


def layer_forward(lp: Params, x: jax.Array, cfg: ModelConfig, *, positions,
                  window: int, ffn: str, need_cache: bool = False,
                  ssm_state=None):
    """Full-sequence layer. Returns (x, aux, cache_contrib)."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    cache_kv = None
    aux = jnp.float32(0.0)
    branch = 0.0
    if cfg.attention == "gqa":
        a = attn.gqa_forward(lp["attn"], h, cfg=cfg, positions=positions,
                             window=window)
        if cfg.parallel_ssm:
            a = rms_norm(a, lp["attn_norm"], cfg.norm_eps)
        branch = branch + a
        if need_cache:
            cache_kv = attn.gqa_prefill_kv(lp["attn"], h, cfg=cfg,
                                           positions=positions)
    elif cfg.attention == "mla":
        branch = branch + attn.mla_forward(lp["attn"], h, cfg=cfg,
                                           positions=positions)
        if need_cache:
            _, _, c_kv, k_rope = attn._mla_qkv_latent(lp["attn"], h, cfg=cfg,
                                                      positions=positions)
            cache_kv = (c_kv, k_rope)
    new_ssm_state = None
    if cfg.ssm is not None:
        if need_cache or ssm_state is not None:
            s_out, new_ssm_state = ssm_mod.mamba_forward(
                lp["ssm"], h, cfg, state=ssm_state, return_state=True)
        else:
            s_out = ssm_mod.mamba_forward(lp["ssm"], h, cfg)
        if cfg.parallel_ssm:
            s_out = rms_norm(s_out, lp["ssm_norm"], cfg.norm_eps)
            branch = 0.5 * (branch + s_out)
        else:
            branch = branch + s_out
    x = x + branch.astype(x.dtype)
    x = with_logical_constraint(x, "batch", "seq", "act_embed")

    if "ffn" in lp:
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + dense_ffn(h2, lp["ffn"], cfg.ffn_act).astype(x.dtype)
    elif "moe" in lp:
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        y, aux = moe_mod.moe_ffn(lp["moe"], h2, cfg)
        x = x + y.astype(x.dtype)
    x = with_logical_constraint(x, "batch", "seq", "act_embed")
    return x, aux, (cache_kv, new_ssm_state)


def layer_decode(lp: Params, x: jax.Array, cache, cfg: ModelConfig, *,
                 positions, window: int):
    """One-token layer step. cache: dict possibly holding kv/ssm/cross caches."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    new_cache = dict(cache)
    branch = 0.0
    if "xattn" in lp:  # enc-dec decoder layer: self-attn then cross-attn
        a, kv = attn.gqa_decode(lp["attn"], h, cache["kv"], cfg=cfg,
                                positions=positions, window=window)
        x = x + a.astype(x.dtype)
        new_cache["kv"] = kv
        hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        ek, ev = cache["cross"]
        x = x + attn.cross_attention(lp["xattn"], hx, ek, ev,
                                     cfg=cfg).astype(x.dtype)
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + dense_ffn(h2, lp["ffn"], cfg.ffn_act).astype(x.dtype)
        return x, new_cache
    if cfg.attention == "gqa":
        a, kv = attn.gqa_decode(lp["attn"], h, cache["kv"], cfg=cfg,
                                positions=positions, window=window)
        if cfg.parallel_ssm:
            a = rms_norm(a, lp["attn_norm"], cfg.norm_eps)
        branch = branch + a
        new_cache["kv"] = kv
    elif cfg.attention == "mla":
        a, kv = attn.mla_decode(lp["attn"], h, cache["kv"], cfg=cfg,
                                positions=positions)
        branch = branch + a
        new_cache["kv"] = kv
    if cfg.ssm is not None:
        s_out, st = ssm_mod.mamba_decode(lp["ssm"], h, cache["ssm"], cfg)
        if cfg.parallel_ssm:
            s_out = rms_norm(s_out, lp["ssm_norm"], cfg.norm_eps)
            branch = 0.5 * (branch + s_out)
        else:
            branch = branch + s_out
        new_cache["ssm"] = st
    x = x + branch.astype(x.dtype)

    if "ffn" in lp:
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + dense_ffn(h2, lp["ffn"], cfg.ffn_act).astype(x.dtype)
    elif "moe" in lp:
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(lp["moe"], h2, cfg)
        x = x + y.astype(x.dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _scan_stack(stack_params, x, body, cfg: ModelConfig,
                need_cache: bool = False, per_layer=None):
    """lax.scan over a homogeneous stacked layer group; accumulates aux and
    (optionally) collects per-layer cache contributions as stacked ys.
    ``per_layer``: extra scanned inputs (e.g. per-layer window widths)."""
    def f(carry, xs):
        lp, extra = xs
        x, aux = carry
        x, a, cache = body(lp, x, extra)
        return (x, aux + a), (cache if need_cache else None)

    f = jax.checkpoint(f, policy=_remat_policy(cfg))
    if per_layer is None:
        per_layer = jnp.zeros((jax.tree.leaves(stack_params)[0].shape[0],),
                              jnp.int32)
    (x, aux), caches = jax.lax.scan(f, (x, jnp.float32(0.0)),
                                    (stack_params, per_layer))
    return x, aux, caches


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if cfg.remat == "none":
        return jax.checkpoint_policies.everything_saveable
    return None  # full remat


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (0 = full), as scanned data."""
    return jnp.array([_layer_window(cfg, i) for i in range(cfg.num_layers)],
                     jnp.int32)


def decoder_forward(params: Params, x: jax.Array, cfg: ModelConfig, *,
                    positions, need_cache: bool = False):
    """Runs the decoder stack on embedded inputs -> (hidden, aux, caches)."""
    aux = jnp.float32(0.0)
    caches: Any = {}
    if "layers_dense" in params:
        body = lambda lp, x, w: layer_forward(
            lp, x, cfg, positions=positions, window=cfg.sliding_window,
            ffn="dense", need_cache=need_cache)
        x, a, c = _scan_stack(params["layers_dense"], x, body, cfg,
                              need_cache)
        aux += a
        caches["dense"] = c
    ffn = "moe" if cfg.is_moe else "dense"
    per_layer = layer_windows(cfg) if cfg.global_attn_layers else None
    if per_layer is not None:
        body = lambda lp, x, w: layer_forward(
            lp, x, cfg, positions=positions, window=w, ffn=ffn,
            need_cache=need_cache)
    else:
        body = lambda lp, x, w: layer_forward(
            lp, x, cfg, positions=positions, window=cfg.sliding_window,
            ffn=ffn, need_cache=need_cache)
    x, a, c = _scan_stack(params["layers"], x, body, cfg, need_cache,
                          per_layer=per_layer)
    aux += a
    caches["main"] = c
    if not need_cache:
        caches = None
    return x, aux, caches


def encoder_forward(params: Params, frames: jax.Array, cfg: ModelConfig):
    """Whisper-style encoder over (stubbed) frame embeddings (B,T,d)."""
    b, t = frames.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def f(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attn.encoder_attention(lp["attn"], h, cfg=cfg,
                                       positions=positions).astype(x.dtype)
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + dense_ffn(h2, lp["ffn"], cfg.ffn_act).astype(x.dtype)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(f, policy=_remat_policy(cfg)),
                        frames, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def encdec_decoder_forward(params: Params, x: jax.Array, enc_out: jax.Array,
                           cfg: ModelConfig, *, positions,
                           need_cache: bool = False):
    """Whisper decoder: self-attn + cross-attn + ffn per layer (scanned)."""
    def f(carry, lp):
        x = carry
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attn.gqa_forward(lp["attn"], h, cfg=cfg, positions=positions,
                                 window=0).astype(x.dtype)
        hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        ek, ev = attn.cross_kv(lp["xattn"], enc_out)
        x = x + attn.cross_attention(lp["xattn"], hx, ek, ev,
                                     cfg=cfg).astype(x.dtype)
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + dense_ffn(h2, lp["ffn"], cfg.ffn_act).astype(x.dtype)
        outs = None
        if need_cache:
            kv = attn.gqa_prefill_kv(lp["attn"], h, cfg=cfg, positions=positions)
            outs = (kv, (ek, ev))
        return x, outs

    x, caches = jax.lax.scan(jax.checkpoint(f, policy=_remat_policy(cfg)),
                             x, params["layers"])
    return x, caches


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    return with_logical_constraint(x, "batch", "seq", "act_embed")


def lm_logits(params: Params, x: jax.Array, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return with_logical_constraint(logits, "batch", "seq", "act_vocab")


def mtp_forward(params: Params, h: jax.Array, tokens: jax.Array,
                cfg: ModelConfig, *, positions):
    """DeepSeek-V3 MTP (depth 1): combine final hidden h_t with embedding of
    token_{t+1}; the shared head then predicts token_{t+2}."""
    mp = params["mtp"]
    emb_next = embed_tokens(params, tokens, cfg)           # (B,S,d) of t+1 toks
    h_n = rms_norm(h, mp["norm_h"], cfg.norm_eps)
    e_n = rms_norm(emb_next, mp["norm_e"], cfg.norm_eps)
    z = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h_n, e_n], axis=-1),
                   mp["proj"])
    z, _, _ = layer_forward(mp["layer"], z, cfg, positions=positions,
                            window=cfg.sliding_window, ffn="dense")
    z = rms_norm(z, mp["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", z, head)
    return with_logical_constraint(logits, "batch", "seq", "act_vocab")
