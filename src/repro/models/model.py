"""build_model(cfg) -> Model: a functional bundle exposing

  specs                    parameter ParamSpec tree
  init(key)                materialize params
  train_forward(p, batch)  -> {"logits", "aux", ["mtp_logits"]}
  prefill(p, batch, max_len) -> (last_logits, cache)
  decode(p, cache, tokens, positions, ...) -> (logits, cache)
  cache_spec(batch, max_len) -> pytree of (shape, logical_axes)

Cache layouts are canonical per family (see models/attention.py docstring);
decode for scanned stacks runs jax.lax.scan over (layer_params, layer_cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.common import init_params, rms_norm
from repro.parallel.sharding import with_logical_constraint


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    specs: Dict[str, Any]
    init: Callable
    train_forward: Callable
    prefill: Callable
    decode: Callable
    cache_spec: Callable

    def token_seq_len(self, seq_len: int) -> int:
        """Text-token count for a given total sequence length."""
        return seq_len - self.cfg.vision_tokens


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _positions(b: int, s: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token (+modality) embedding -> (x, positions)."""
    tokens = batch["tokens"]
    x = tfm.embed_tokens(params, tokens, cfg)
    if cfg.vision_tokens:
        pe = batch["patch_embeds"].astype(x.dtype)         # (B, Nv, Dv)
        v = jnp.einsum("bnd,dk->bnk", pe, params["proj1"])
        v = jax.nn.gelu(v.astype(jnp.float32)).astype(x.dtype)
        v = jnp.einsum("bnk,kd->bnd", v, params["proj2"])
        x = jnp.concatenate([v, x], axis=1)
    b, s = x.shape[:2]
    return x, _positions(b, s)


def _kv_cache_from_prefill(kv, positions, max_len: int, window: int):
    """(k,v) stacked (L,B,S,nkv,hd) -> decode cache {"k","v","pos"}."""
    k, v = kv
    l, b, s = k.shape[:3]
    sc = min(max_len, window) if window else max_len
    if not window or s <= sc:
        pad = sc - min(s, sc)
        take = min(s, sc)
        kc = jnp.pad(k[:, :, :take], ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 3))
        vc = jnp.pad(v[:, :, :take], ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 3))
        pos = jnp.pad(positions[:, :take], ((0, 0), (0, pad)), constant_values=-1)
    else:
        shift = (s - sc) % sc
        kc = jnp.roll(k[:, :, -sc:], shift, axis=2)
        vc = jnp.roll(v[:, :, -sc:], shift, axis=2)
        pos = jnp.roll(positions[:, -sc:], shift, axis=1)
    pos = jnp.broadcast_to(pos[None], (l,) + pos.shape)
    return {"k": kc, "v": vc, "pos": pos.astype(jnp.int32)}


def _mla_cache_from_prefill(kv, positions, max_len: int):
    c_kv, k_rope = kv
    l, b, s = c_kv.shape[:3]
    pad = max_len - s
    cc = jnp.pad(c_kv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    rc = jnp.pad(k_rope, ((0, 0), (0, 0), (0, pad), (0, 0)))
    pos = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    pos = jnp.broadcast_to(pos[None], (l,) + pos.shape)
    return {"c_kv": cc, "k_rope": rc, "pos": pos.astype(jnp.int32)}


def _scan_decode(stack_params, stack_cache, x, cfg: ModelConfig, positions,
                 window: int):
    """Decode through a scanned layer stack with the cache held in the scan
    CARRY and updated in place at the layer index.

    Passing the cache as scan xs/ys instead forces XLA to materialize a
    fresh stacked-cache output every step (measured: ~150x the unavoidable
    cache+param traffic on deepseek-v3 decode); a carry with
    dynamic-update-slice writes is in-place eligible in the compiled while
    loop, so each iteration touches only its own layer's slice.
    """
    n_layers = jax.tree.leaves(stack_params)[0].shape[0]

    def f(carry, xs):
        x, full = carry
        lp, i = xs
        lc = jax.tree.map(lambda t: jax.lax.dynamic_index_in_dim(
            t, i, 0, keepdims=False), full)
        x, nc = tfm.layer_decode(lp, x, lc, cfg, positions=positions,
                                 window=window)
        full = jax.tree.map(
            lambda t, new: jax.lax.dynamic_update_index_in_dim(
                t, new.astype(t.dtype), i, 0), full, nc)
        return (x, full), None

    (x, new_cache), _ = jax.lax.scan(
        f, (x, stack_cache),
        (stack_params, jnp.arange(n_layers, dtype=jnp.int32)))
    return x, new_cache


def _stack_cache_spec(cfg: ModelConfig, num_layers: int, batch: int,
                      max_len: int, window: int):
    """(shape, logical) specs for one scanned stack's decode cache."""
    out: Dict[str, Any] = {}
    if cfg.attention == "gqa":
        spec = attn.init_gqa_cache_spec(cfg, batch, max_len, window)
        out["kv"] = {k: ((num_layers,) + sh, ("layers",) + lg)
                     for k, (sh, lg) in spec.items()}
    elif cfg.attention == "mla":
        spec = attn.init_mla_cache_spec(cfg, batch, max_len)
        out["kv"] = {k: ((num_layers,) + sh, ("layers",) + lg)
                     for k, (sh, lg) in spec.items()}
    if cfg.ssm is not None:
        spec = ssm_mod.init_ssm_state_spec(cfg, batch)
        out["ssm"] = {k: ((num_layers,) + sh, ("layers",) + lg)
                      for k, (sh, lg) in spec.items()}
    return out


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> Model:
    specs = tfm.model_specs(cfg)

    def init(key):
        return init_params(key, specs)

    # ------------------------------ train ---------------------------------
    def train_forward(params, batch):
        if cfg.encoder_layers:
            enc_out = tfm.encoder_forward(params, batch["frames"].astype(
                jnp.dtype(cfg.dtype)), cfg)
            tokens = batch["tokens"]
            x = tfm.embed_tokens(params, tokens, cfg)
            pos = _positions(*tokens.shape)
            x, _ = tfm.encdec_decoder_forward(params, x, enc_out, cfg,
                                              positions=pos)
            return {"logits": tfm.lm_logits(params, x, cfg),
                    "aux": jnp.float32(0.0)}
        x, pos = _embed_inputs(params, batch, cfg)
        h, aux, _ = tfm.decoder_forward(params, x, cfg, positions=pos)
        out = {"aux": aux}
        if cfg.vision_tokens:
            h = h[:, cfg.vision_tokens:]
            pos = pos[:, cfg.vision_tokens:]
        out["logits"] = tfm.lm_logits(params, h, cfg)
        if cfg.mtp_depth:
            nxt = jnp.roll(batch["tokens"], -1, axis=1)
            out["mtp_logits"] = tfm.mtp_forward(params, h, nxt, cfg,
                                                positions=pos)
        return out

    # ----------------------------- prefill --------------------------------
    def prefill(params, batch, max_len: int):
        if cfg.encoder_layers:
            enc_out = tfm.encoder_forward(params, batch["frames"].astype(
                jnp.dtype(cfg.dtype)), cfg)
            tokens = batch["tokens"]
            x = tfm.embed_tokens(params, tokens, cfg)
            pos = _positions(*tokens.shape)
            x, collected = tfm.encdec_decoder_forward(
                params, x, enc_out, cfg, positions=pos, need_cache=True)
            kv, cross = collected
            cache = {"main": {
                "kv": _kv_cache_from_prefill(kv, pos, max_len, 0),
                "cross": cross,
            }}
            logits = tfm.lm_logits(params, x[:, -1:], cfg)
            return logits[:, 0], cache

        x, pos = _embed_inputs(params, batch, cfg)
        h, _, collected = tfm.decoder_forward(params, x, cfg, positions=pos,
                                              need_cache=True)
        cache: Dict[str, Any] = {}
        if cfg.parallel_ssm:  # hybrid: scanned stack, per-layer cache windows
            kv, st = collected["main"]
            per_layer = []
            for i in range(cfg.num_layers):
                w = tfm._layer_window(cfg, i)
                entry: Dict[str, Any] = {}
                if kv is not None:
                    one = jax.tree.map(lambda t: t[i:i + 1], kv)
                    c = _kv_cache_from_prefill(one, pos, max_len, w)
                    entry["kv"] = jax.tree.map(lambda t: t[0], c)
                if st is not None:
                    entry["ssm"] = jax.tree.map(lambda t: t[i], st)
                per_layer.append(entry)
            cache = tuple(per_layer)
        else:
            for name, c in collected.items():
                kv, st = c
                entry = {}
                if kv is not None:
                    if cfg.attention == "mla":
                        entry["kv"] = _mla_cache_from_prefill(kv, pos, max_len)
                    else:
                        entry["kv"] = _kv_cache_from_prefill(
                            kv, pos, max_len, cfg.sliding_window)
                if st is not None:
                    entry["ssm"] = st
                cache[name] = entry
        logits = tfm.lm_logits(params, h[:, -1:], cfg)
        return logits[:, 0], cache

    # ------------------------------ decode --------------------------------
    def decode(params, cache, tokens, positions):
        """tokens: (B,1) int32; positions: (B,) absolute position."""
        x = tfm.embed_tokens(params, tokens, cfg)
        new_cache: Any
        if cfg.parallel_ssm:
            new_layers = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda t: t[i], params["layers"])
                w = tfm._layer_window(cfg, i)
                x, nc = tfm.layer_decode(lp, x, cache[i], cfg,
                                         positions=positions, window=w)
                new_layers.append(nc)
            new_cache = tuple(new_layers)
        elif cfg.encoder_layers:
            x, nc = _scan_decode(params["layers"], cache["main"], x, cfg,
                                 positions, window=0)
            new_cache = {"main": nc}
        else:
            new_cache = {}
            if "layers_dense" in params:
                x, nc = _scan_decode(params["layers_dense"], cache["dense"],
                                     x, cfg, positions,
                                     window=cfg.sliding_window)
                new_cache["dense"] = nc
            x, nc = _scan_decode(params["layers"], cache["main"], x, cfg,
                                 positions, window=cfg.sliding_window)
            new_cache["main"] = nc
        logits = tfm.lm_logits(params, x, cfg)
        return logits[:, 0], new_cache

    # ---------------------------- cache spec -------------------------------
    def cache_spec(batch: int, max_len: int):
        if cfg.parallel_ssm:
            per_layer = []
            for i in range(cfg.num_layers):
                w = tfm._layer_window(cfg, i)
                entry = {}
                s = attn.init_gqa_cache_spec(cfg, batch, max_len, w)
                entry["kv"] = s
                entry["ssm"] = ssm_mod.init_ssm_state_spec(cfg, batch)
                per_layer.append(entry)
            return tuple(per_layer)
        if cfg.encoder_layers:
            spec = _stack_cache_spec(cfg, cfg.num_layers, batch, max_len, 0)
            nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            t = cfg.encoder_seq_len
            l = cfg.num_layers
            spec["cross"] = (
                ((l, batch, t, nkv, hd),
                 ("layers", "batch", None, "act_kv_heads", "act_head_dim")),
                ((l, batch, t, nkv, hd),
                 ("layers", "batch", None, "act_kv_heads", "act_head_dim")),
            )
            return {"main": spec}
        out = {}
        if cfg.is_moe and cfg.moe.first_k_dense:
            out["dense"] = _stack_cache_spec(cfg, cfg.moe.first_k_dense, batch,
                                             max_len, cfg.sliding_window)
            out["main"] = _stack_cache_spec(
                cfg, cfg.num_layers - cfg.moe.first_k_dense, batch, max_len,
                cfg.sliding_window)
        else:
            out["main"] = _stack_cache_spec(cfg, cfg.num_layers, batch,
                                            max_len, cfg.sliding_window)
        return out

    return Model(cfg=cfg, specs=specs, init=init, train_forward=train_forward,
                 prefill=prefill, decode=decode, cache_spec=cache_spec)
