"""Mixture-of-Experts FFN: token-choice top-k routing with GShard-style
*group-local* capacity dispatch (groups = batch rows), scatter/gather based so
the (tokens, experts, capacity) dispatch tensor never materializes.

Group-locality matters under SPMD: the position-in-expert cumsum runs along
the *unsharded* (seq*k) dim, so GSPMD never has to do a cross-shard prefix
sum; the only collective introduced is the (group-sharded -> expert-sharded)
resharding around the expert einsums, i.e. the all-to-all an MoE layer is
supposed to have.

Supports Mixtral (8e top-2, softmax router + Switch aux loss) and DeepSeek-V3
(256 routed + 1 shared, top-8, sigmoid router with aux-free bias balancing,
routed_scaling_factor). Expert weights carry the "expert" logical axis →
expert parallelism over the "model" mesh axis; when |experts| < |axis| the
rules fall back to TP over the expert mlp dim (see parallel/sharding.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import ParamSpec
from repro.parallel.sharding import with_logical_constraint


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    e = cfg.moe
    ne, ns, f = e.num_experts, e.num_shared_experts, e.expert_d_ff
    specs = {
        "router": ParamSpec((d, ne), ("embed", "expert"), "scaled",
                            dtype=jnp.float32),
        "w_gate": ParamSpec((ne, d, f), ("expert", "expert_embed", "expert_mlp"), "scaled"),
        "w_up": ParamSpec((ne, d, f), ("expert", "expert_embed", "expert_mlp"), "scaled"),
        "w_down": ParamSpec((ne, f, d), ("expert", "expert_mlp", "expert_embed"), "scaled"),
    }
    if e.router_aux_free:
        specs["router_bias"] = ParamSpec((ne,), ("expert",), "zeros",
                                         dtype=jnp.float32)
    if ns:
        specs["shared_gate"] = ParamSpec((d, ns * f), ("embed", "mlp"), "scaled")
        specs["shared_up"] = ParamSpec((d, ns * f), ("embed", "mlp"), "scaled")
        specs["shared_down"] = ParamSpec((ns * f, d), ("mlp", "embed"), "scaled")
    return specs


def _route(params, x: jax.Array, e: MoEConfig):
    """x: (B, S, D) -> weights (B,S,K), idx (B,S,K) int32, aux scalar."""
    # matmul in the activation dtype, softmax/sigmoid in f32: an f32 input
    # here makes grad_x an f32 (B,S,D) tensor that must be all-reduced over
    # the expert axis — measured at ~40% of deepseek-v3's train collectives
    logits = jnp.einsum("bsd,de->bse", x,
                        params["router"].astype(x.dtype)).astype(jnp.float32)
    if e.router_aux_free:
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, None, :]
        _, idx = jax.lax.top_k(sel, e.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        w = w * e.router_scale
        aux = jnp.float32(0.0)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, e.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        # Switch-style load-balance loss (per group, then averaged)
        me = probs.mean(axis=(0, 1))                       # (E,)
        fe = jax.nn.one_hot(idx[..., 0], e.num_experts,
                            dtype=jnp.float32).mean(axis=(0, 1))
        aux = e.num_experts * jnp.sum(me * fe)
    return w, idx, aux


def _positions_in_expert(flat: jax.Array) -> jax.Array:
    """flat: (G, T) expert ids -> occurrence rank of each id at each slot.

    Stable-sort the ids; within the sorted order an id's occurrences are a
    contiguous run, so rank = index - run_start, where run_start propagates
    by a max-scan. Ranks scatter back through the sort permutation. All
    buffers stay (G, T) int32.
    """
    g, t = flat.shape
    order = jnp.argsort(flat, axis=1, stable=True)         # (G, T)
    sorted_e = jnp.take_along_axis(flat, order, axis=1)
    iota = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (g, t))
    is_start = jnp.concatenate(
        [jnp.ones((g, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, iota, 0), axis=1)
    pos_sorted = iota - run_start
    pos = jnp.zeros_like(flat)
    pos = jax.vmap(lambda p, o, v: p.at[o].set(v))(pos, order, pos_sorted)
    return pos


def moe_ffn(params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss)."""
    e = cfg.moe
    b0, s0, d = x.shape
    k, ne = e.top_k, e.num_experts

    w, idx, aux = _route(params, x, e)

    # decode-time regrouping: with s*k << num_experts the per-row capacity
    # buffer is ~(ne/ (s*k))x empty — merge rows into fewer, fuller groups
    # (target ~2*ne dispatched slots per group) before capacity assignment.
    b, s = b0, s0
    if s0 * k < ne and b0 > 1:
        tpg = max(1, 2 * ne // k)               # tokens per group
        g = max(1, (b0 * s0) // tpg)
        while (b0 * s0) % g:
            g -= 1
        b, s = g, b0 * s0 // g
        x = x.reshape(b, s, d)
        w = w.reshape(b, s, k)
        idx = idx.reshape(b, s, k)
    cap = max(1, int(e.capacity_factor * s * k / ne))

    # --- group-local (per batch row) position-in-expert, sort-based ---
    # (an earlier one-hot+cumsum formulation materialized a (B, S*K, E)
    # int32 tensor per layer — ~540MB/device/layer on deepseek-v3; the sort
    # keeps everything (B, S*K) int32.)
    flat = idx.reshape(b, s * k)                           # (B, S*K)
    pos = _positions_in_expert(flat)
    keep = pos < cap
    dst = jnp.where(keep, flat * cap + pos, ne * cap)      # overflow -> slot E*cap

    # --- scatter tokens into (B, E, C, D) ---
    # vmapped 1-D scatter per group: lowers to a scatter with operand batching
    # dims, which GSPMD partitions along the (sharded) group axis. A flat 2-D
    # index scatter instead makes GSPMD replicate the whole dispatch tensor
    # (observed: a 224 GiB f32 all-gather on deepseek-v3).
    wr = w.reshape(b, s * k).astype(x.dtype)

    def scatter_group(xg, dstg):
        xe = jnp.repeat(xg, k, axis=0)                     # (S*K, D)
        return jnp.zeros((ne * cap + 1, d), x.dtype).at[dstg].add(xe)

    buf = jax.vmap(scatter_group)(x, dst)
    buf = buf[:, :-1].reshape(b, ne, cap, d)
    # two-stage sharding: the scatter itself must stay sharded on its GROUP
    # (batching) dim — GSPMD replicates data-dependent scatter outputs
    # resharded on other dims. The *2 axes then move the buffer to the
    # expert-parallel layout (an explicit all-to-all under EP-2D rules;
    # identical to stage 1 under the default rules, i.e. a no-op).
    buf = with_logical_constraint(buf, "moe_group", "act_expert", "moe_cap", "act_embed")
    buf = with_logical_constraint(buf, "moe_group2", "act_expert2", "moe_cap", "act_embed")

    # --- expert computation (SwiGLU) ---
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = with_logical_constraint(h, "moe_group2", "act_expert2", "moe_cap", "act_mlp")
    y = jnp.einsum("becf,efd->becd", h, params["w_down"])
    y = with_logical_constraint(y, "moe_group2", "act_expert2", "moe_cap", "act_embed")
    # move results back to the group-sharded layout before the gather
    y = with_logical_constraint(y, "moe_group", "act_expert", "moe_cap", "act_embed")

    # --- gather back + combine with router weights (vmapped, see above) ---
    y_flat = y.reshape(b, ne * cap, d)
    dstc = jnp.minimum(dst, ne * cap - 1)
    gathered = jax.vmap(lambda yg, dg: yg[dg])(y_flat, dstc)
    gathered = jnp.where(keep[..., None], gathered, 0)
    combined = (gathered * wr[..., None]).reshape(b, s, k, d).sum(axis=2)
    combined = combined.reshape(b0, s0, d)
    x = x.reshape(b0, s0, d)

    if e.num_shared_experts:
        sg = jnp.einsum("bsd,df->bsf", x, params["shared_gate"])
        su = jnp.einsum("bsd,df->bsf", x, params["shared_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        combined = combined + jnp.einsum("bsf,fd->bsd", sh, params["shared_down"])

    return combined, aux


def router_load(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Per-expert token counts (for aux-free bias updates / telemetry)."""
    e = cfg.moe
    _, idx, _ = _route(params, x, e)
    return jnp.bincount(idx.reshape(-1), length=e.num_experts)
