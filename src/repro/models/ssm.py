"""Mamba-1 selective SSM block: chunked parallel scan (train/prefill) and
single-token recurrence (decode).

The train path splits the sequence into chunks; within a chunk the recurrence
h_t = exp(dt_t*A) h_{t-1} + dt_t*B_t x_t runs as a Blelloch associative scan
(parallel, MXU-friendly), and chunk boundaries carry h with an outer
jax.lax.scan — memory stays O(chunk * d_inner * state) instead of
O(seq * d_inner * state). The Pallas kernel (repro.kernels.selective_scan)
mirrors this chunking with the carry in VMEM scratch.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.parallel.sharding import with_logical_constraint


def ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    dt = s.resolved_dt_rank(d)
    n = s.state_dim
    return {
        "w_in": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), "scaled"),
        "conv_w": ParamSpec((s.conv_kernel, di), ("conv_k", "ssm_inner"), "scaled"),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        "w_x": ParamSpec((di, dt + 2 * n), ("ssm_inner", "dt_rank"), "scaled"),
        "w_dt": ParamSpec((dt, di), ("dt_rank", "ssm_inner"), "scaled"),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), "mamba_dt", dtype=jnp.float32),
        "a_log": ParamSpec((di, n), ("ssm_inner", "ssm_state"), "mamba_a",
                           dtype=jnp.float32),
        "d_skip": ParamSpec((di,), ("ssm_inner",), "ones", dtype=jnp.float32),
        "w_out": ParamSpec((di, d), ("ssm_inner", "embed"), "scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """x: (B,S,di); w: (k,di) depthwise. state: (B,k-1,di) carried history."""
    k = w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)                # (B, S+k-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else hist
    return out, new_state


def _chunk_scan(da: jax.Array, bx: jax.Array, h0: jax.Array):
    """Associative scan of h_t = da_t * h_{t-1} + bx_t within one chunk.

    da, bx: (B, c, di, n) fp32; h0: (B, di, n). Returns (ys_states, h_end).
    """
    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    # fold the incoming state into the first step
    bx = bx.at[:, 0].add(da[:, 0] * h0)
    decay, states = jax.lax.associative_scan(combine, (da, bx), axis=1)
    return states, states[:, -1]


def selective_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b_ssm: jax.Array,
                   c_ssm: jax.Array, d_skip: jax.Array,
                   h0: jax.Array | None = None, chunk: int = 256,
                   scan_dtype=jnp.float32):
    """x, dt: (B,S,di); a: (di,n); b_ssm, c_ssm: (B,S,n). Returns y, h_end.

    scan_dtype: dtype of the associative-scan operands (decay/state). bf16
    halves the dominant HBM traffic of SSM training at ~1e-2 relative state
    drift over a 256-step chunk (chunk boundaries re-enter in fp32).
    """
    bsz, s, di = x.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ssm = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_ssm = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
    nchunk = x.shape[1] // chunk
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    def chunk_body(h, xs):
        xc, dtc, bc, cc = xs                               # (B,c,di) / (B,c,n)
        da = jnp.exp(dtc[..., None] * a[None, None])       # (B,c,di,n)
        bx = (dtc * xc)[..., None] * bc[:, :, None, :]     # (B,c,di,n)
        states, h_end = _chunk_scan(da.astype(scan_dtype),
                                    bx.astype(scan_dtype),
                                    h.astype(scan_dtype))
        y = jnp.einsum("bcdn,bcn->bcd", states, cc.astype(scan_dtype))
        return h_end.astype(jnp.float32), y.astype(x.dtype)

    split = lambda t: t.reshape(bsz, nchunk, chunk, -1).transpose(1, 0, 2, 3)
    xs = (split(x), split(dt.astype(jnp.float32)),
          split(b_ssm.astype(jnp.float32)), split(c_ssm.astype(jnp.float32)))
    h_end, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, nchunk * chunk, di)[:, :s]
    # keep the residual path in the activation dtype: an f32 hop here makes
    # every backward cotangent (and the scan's saved-input stash) f32 —
    # observed as a 2x HBM-traffic + stash blowup on falcon-mamba train
    return y + x[:, :s] * d_skip.astype(x.dtype), h_end


def mamba_forward(params, x: jax.Array, cfg: ModelConfig,
                  state: Dict[str, jax.Array] | None = None,
                  return_state: bool = False):
    """Full-sequence mamba block. x: (B,S,d). Optionally carries/returns state
    {"conv": (B,k-1,di), "ssm": (B,di,n)} for prefill->decode handoff."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.expand * d
    dtr = s_cfg.resolved_dt_rank(d)
    n = s_cfg.state_dim

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xz = with_logical_constraint(xz, "batch", "seq", "act_ssm_inner")
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xi, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bsd,de->bse", xc, params["w_x"])
    dt_low, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_low, params["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    h0 = state["ssm"] if state is not None else None
    y, h_end = selective_scan(xc, dt, a, b_ssm, c_ssm, params["d_skip"], h0=h0,
                              scan_dtype=jnp.dtype(s_cfg.scan_dtype))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"])
    if return_state:
        return out, {"conv": new_conv, "ssm": h_end}
    return out


def init_ssm_state_spec(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": ((batch, s.conv_kernel - 1, di), ("batch", None, "act_ssm_inner")),
        "ssm": ((batch, di, s.state_dim), ("batch", "act_ssm_inner", "ssm_state")),
    }


def mamba_decode(params, x: jax.Array, state: Dict[str, jax.Array],
                 cfg: ModelConfig):
    """Single-token recurrence. x: (B,1,d)."""
    s_cfg = cfg.ssm
    dtr = s_cfg.resolved_dt_rank(cfg.d_model)
    n = s_cfg.state_dim

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)                      # (B,1,di)
    # conv over (history ++ new)
    k = params["conv_w"].shape[0]
    hist = state["conv"].astype(x.dtype)                   # (B,k-1,di)
    window = jnp.concatenate([hist, xi], axis=1)           # (B,k,di)
    xc = (window * params["conv_w"][None]).sum(axis=1, keepdims=True) + params["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    new_conv = window[:, 1:]

    proj = jnp.einsum("bsd,de->bse", xc, params["w_x"])
    dt_low, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_low, params["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])[:, 0]     # (B,di)
    a = -jnp.exp(params["a_log"])

    h = state["ssm"]                                       # (B,di,n)
    da = jnp.exp(dt[..., None] * a[None])
    bx = (dt * xc[:, 0].astype(jnp.float32))[..., None] * b_ssm[:, 0, None, :].astype(jnp.float32)
    h_new = da * h + bx
    y = jnp.einsum("bdn,bn->bd", h_new, c_ssm[:, 0].astype(jnp.float32))
    y = (y + xc[:, 0].astype(jnp.float32) * params["d_skip"]).astype(x.dtype)[:, None]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"])
    return out, {"conv": new_conv, "ssm": h_new}
