"""Attention: GQA (full / sliding-window) and MLA (DeepSeek), train + decode.

Pure-jnp math (query-chunked, fp32 softmax) so every cell lowers for the
dry-run on any backend; the Pallas flash kernel (repro.kernels.flash_attention)
is an opt-in drop-in for the TPU target, validated against this path.

Decode uses a unified cache layout: (B, Sc, nkv, hd) K/V plus a (B, Sc) int32
``pos`` array holding the absolute position stored in each slot (-1 = empty).
A rolling (sliding-window) cache is the same structure with Sc = window and
slot = pos % Sc, so full and SWA caches share one code path. MLA decode caches
the compressed latent (kv_lora_rank + rope_dim per token) and uses the
absorbed-matmul trick, which is the point of MLA's serving efficiency.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.common import ParamSpec, apply_rope, rms_norm
from repro.parallel.sharding import with_logical_constraint

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": ParamSpec((d, nq, hd), ("embed", "heads", "head_dim"), "scaled"),
        "wk": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wv": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wo": ParamSpec((nq, hd, d), ("heads", "head_dim", "embed"), "scaled"),
    }


def _attend_chunked(q, k, v, *, q_positions, kv_positions, causal: bool,
                    window: int, chunk: int = 1024, kv_valid=None):
    """q: (B,S,nkv,g,hd); k,v: (B,Skv,nkv,hd). fp32 online softmax per q-chunk."""
    b, s, nkv, g, hd = q.shape
    scale = hd ** -0.5
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    nchunk = q.shape[1] // chunk
    qs = q.reshape(b, nchunk, chunk, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qpos = q_positions.reshape(b, nchunk, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        qc, qp = xs                                     # (B,c,nkv,g,hd), (B,c)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((b, 1, 1, chunk, k.shape[1]), jnp.bool_)
        rel = qp[:, :, None] - kv_positions[:, None, :]  # (B,c,Skv)
        if causal:
            mask &= (rel >= 0)[:, None, None]
        if isinstance(window, jax.Array):
            # traced per-layer window (scanned hybrid stacks); 0 = full attn
            mask &= ((window <= 0) | (rel < window))[:, None, None]
        elif window:
            mask &= (rel < window)[:, None, None]
        if kv_valid is not None:
            mask &= kv_valid[:, None, None, None, :]
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
        return carry, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, qpos))
    vd = v.shape[-1]  # may differ from q head_dim (MLA)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s + pad, nkv, g, vd)
    return out[:, :s]


def gqa_forward(params, x, *, cfg: ModelConfig, positions, window: int,
                chunk: int = 1024) -> jax.Array:
    """Full-sequence (train / prefill) GQA with RoPE."""
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nq // nkv
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = with_logical_constraint(q, "batch", "seq", "act_heads", "act_head_dim")
    k = with_logical_constraint(k, "batch", "seq", "act_kv_heads", "act_head_dim")
    v = with_logical_constraint(v, "batch", "seq", "act_kv_heads", "act_head_dim")
    qg = q.reshape(q.shape[0], q.shape[1], nkv, g, hd)
    out = _attend_chunked(qg, k, v, q_positions=positions,
                          kv_positions=positions, causal=True, window=window,
                          chunk=chunk)
    out = out.reshape(out.shape[0], out.shape[1], nq, hd)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def gqa_prefill_kv(params, x, *, cfg: ModelConfig, positions):
    """K/V for cache population during prefill (post-RoPE)."""
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def init_gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                        window: int) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    sc = min(max_len, window) if window else max_len
    nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    long = max_len >= 2 ** 18 or batch == 1
    seq_ax = "long_seq" if long else "kv_seq"
    return {
        "k": ((batch, sc, nkv, hd), ("batch", seq_ax, "act_kv_heads", "act_head_dim")),
        "v": ((batch, sc, nkv, hd), ("batch", seq_ax, "act_kv_heads", "act_head_dim")),
        "pos": ((batch, sc), ("batch", seq_ax)),
    }


def gqa_decode(params, x, cache, *, cfg: ModelConfig, positions,
               window: int):
    """One-token decode. x: (B,1,d); positions: (B,) absolute position."""
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nq // nkv
    b = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    pos2 = positions[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    sc = cache["k"].shape[1]
    slot = positions % sc
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    pos_cache = cache["pos"].at[bidx, slot].set(positions)

    if cfg.decode_kernel:  # Pallas flash-decoding kernel (TPU target)
        from repro.kernels.decode_attention.ops import decode_attention_op
        out = decode_attention_op(q[:, 0], k_cache, v_cache, pos_cache,
                                  positions, window=window,
                                  impl="auto" if jax.default_backend() == "tpu"
                                  else "interpret")
        out = out[:, None]                               # (B,1,Nq,Hd)
    else:
        scale = hd ** -0.5
        qg = q.reshape(b, 1, nkv, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                            preferred_element_type=jnp.float32) * scale
        rel = positions[:, None] - pos_cache             # (B,Sc)
        valid = (pos_cache >= 0) & (rel >= 0)
        if window:
            valid &= rel < window
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_cache.dtype),
                         v_cache)
        out = out.reshape(b, 1, nq, hd)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, {"k": k_cache, "v": v_cache, "pos": pos_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2/V3)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, nq, m = cfg.d_model, cfg.num_heads, cfg.mla
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "mla_rank"), "scaled"),
        "q_norm": ParamSpec((m.q_lora_rank,), ("mla_rank",), "ones"),
        "wq_b": ParamSpec((m.q_lora_rank, nq, qh), ("mla_rank", "heads", "head_dim"), "scaled"),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", "mla_rank"), "scaled"),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("mla_rank",), "ones"),
        "wk_b": ParamSpec((m.kv_lora_rank, nq, m.qk_nope_head_dim),
                          ("mla_rank", "heads", "head_dim"), "scaled"),
        "wv_b": ParamSpec((m.kv_lora_rank, nq, m.v_head_dim),
                          ("mla_rank", "heads", "head_dim"), "scaled"),
        "wo": ParamSpec((nq, m.v_head_dim, d), ("heads", "head_dim", "embed"), "scaled"),
    }


def _mla_qkv_latent(params, x, *, cfg: ModelConfig, positions):
    """Shared projection path: returns per-head q (nope+rope), latent c_kv,
    shared k_rope (post-RoPE)."""
    m = cfg.mla
    q_lat = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q_lat = rms_norm(q_lat, params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", q_lat, params["wq_b"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = rms_norm(kv[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def mla_forward(params, x, *, cfg: ModelConfig, positions,
                chunk: int = 1024) -> jax.Array:
    """Train/prefill MLA: expand latent to per-head K/V (standard training path)."""
    m = cfg.mla
    nq = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(params, x, cfg=cfg,
                                                   positions=positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, params["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope_head_dim,))], axis=-1)
    q = with_logical_constraint(q, "batch", "seq", "act_heads", "act_head_dim")
    k = with_logical_constraint(k, "batch", "seq", "act_heads", "act_head_dim")
    v = with_logical_constraint(v, "batch", "seq", "act_heads", "act_head_dim")
    qg = q[:, :, :, None, :]                            # g=1 (nkv == nq here)
    out = _attend_chunked(qg, k, v, q_positions=positions,
                          kv_positions=positions, causal=True, window=0,
                          chunk=chunk)
    out = out[..., 0, :]
    # NB: scale uses the full qk head dim
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def init_mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {
        "c_kv": ((batch, max_len, m.kv_lora_rank), ("batch", "kv_seq", "mla_rank")),
        "k_rope": ((batch, max_len, m.qk_rope_head_dim), ("batch", "kv_seq", None)),
        "pos": ((batch, max_len), ("batch", "kv_seq")),
    }


def mla_decode(params, x, cache, *, cfg: ModelConfig, positions):
    """Absorbed-matmul MLA decode against the compressed latent cache."""
    m = cfg.mla
    nq = cfg.num_heads
    b = x.shape[0]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv_latent(
        params, x, cfg=cfg, positions=positions[:, None])
    slot = positions % cache["c_kv"].shape[1]
    bidx = jnp.arange(b)
    c_cache = cache["c_kv"].at[bidx, slot].set(c_kv_new[:, 0].astype(cache["c_kv"].dtype))
    r_cache = cache["k_rope"].at[bidx, slot].set(k_rope_new[:, 0].astype(cache["k_rope"].dtype))
    pos_cache = cache["pos"].at[bidx, slot].set(positions)
    # absorb: q_lat[b,h,r] = q_nope[b,h,e] @ wk_b[r,h,e]
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, params["wk_b"])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_cache,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, r_cache,
                           preferred_element_type=jnp.float32)) * scale
    valid = (pos_cache >= 0) & (pos_cache <= positions[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(c_cache.dtype), c_cache)
    out = jnp.einsum("bshr,rhe->bshe", out_lat, params["wv_b"])
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, {"c_kv": c_cache, "k_rope": r_cache, "pos": pos_cache}


# ---------------------------------------------------------------------------
# Bidirectional (encoder) + cross attention, for the enc-dec (whisper) family
# ---------------------------------------------------------------------------

def encoder_attention(params, x, *, cfg: ModelConfig, positions):
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nq // nkv
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qg = q.reshape(q.shape[0], q.shape[1], nkv, g, hd)
    out = _attend_chunked(qg, k, v, q_positions=positions,
                          kv_positions=positions, causal=False, window=0)
    out = out.reshape(out.shape[0], out.shape[1], nq, hd)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def cross_attention(params, x, enc_k, enc_v, *, cfg: ModelConfig):
    """x: (B,S,d) decoder side; enc_k/enc_v: (B,T,nkv,hd) precomputed."""
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nq // nkv
    b, s = x.shape[:2]
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    qg = q.reshape(b, s, nkv, g, hd)
    t = enc_k.shape[1]
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.zeros((b, t), jnp.int32)
    out = _attend_chunked(qg, enc_k, enc_v, q_positions=qpos, kv_positions=kpos,
                          causal=False, window=0)
    out = out.reshape(b, s, nq, hd)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def cross_kv(params, enc_out):
    k = jnp.einsum("btd,dhe->bthe", enc_out, params["wk"])
    v = jnp.einsum("btd,dhe->bthe", enc_out, params["wv"])
    return k, v
