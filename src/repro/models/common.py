"""Parameter-spec machinery + shared layer math (norms, RoPE, losses).

Models are pure-functional: ``*_specs(cfg)`` returns a pytree of ParamSpec
(shape + logical axes + initializer); ``init_params`` materializes it,
``abstract_params`` gives ShapeDtypeStructs for allocation-free lowering, and
``param_pspecs`` resolves PartitionSpecs through the AxisRules table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import AxisRules, resolve_pspec, with_logical_constraint


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | scaled | mamba_a | mamba_dt
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def stack_specs(tree, num: int, logical: str = "layers"):
    """Prepend a stacked (scan) dimension to every spec in the tree."""
    def f(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            s, shape=(num,) + s.shape, logical=(logical,) + s.logical)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "mamba_a":
        # A_log init: log of 1..N broadcast over d_inner  (shape (..., d, N))
        n = spec.shape[-1]
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), spec.shape[:-1] + (1,))
        return jnp.log(a).astype(spec.dtype)
    if spec.init == "mamba_dt":
        # dt bias: inverse-softplus of uniform in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(spec.dtype)
    scale = spec.scale
    if spec.init == "scaled":  # 1/sqrt(fan_in)
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def init_params(key, spec_tree):
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(k, s) for k, s in zip(keys, leaves)])


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_pspecs(spec_tree, mesh, rules: AxisRules):
    return jax.tree.map(
        lambda s: resolve_pspec(s.logical, s.shape, mesh, rules),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)))


# ---------------------------------------------------------------------------
# Shared math
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = with_logical_constraint(h, "batch", "seq", "act_mlp")
    return jnp.einsum("...f,fd->...d", h, w_down)


def dense_ffn(x: jax.Array, ffn_params, act: str = "swiglu") -> jax.Array:
    """Dense FFN: 3-matrix SwiGLU or 2-matrix GELU (starcoder2/whisper)."""
    if act == "swiglu":
        return swiglu(x, ffn_params["w_gate"], ffn_params["w_up"],
                      ffn_params["w_down"])
    u = jnp.einsum("...d,df->...f", x, ffn_params["w_up"])
    h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    h = with_logical_constraint(h, "batch", "seq", "act_mlp")
    return jnp.einsum("...f,fd->...d", h, ffn_params["w_down"])


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None,
                       z_loss: float = 0.0):
    """logits (B,S,V) [bf16 ok], labels (B,S) int32. fp32 log-sum-exp."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = float(np.prod(labels.shape))
    return nll.sum() / denom
