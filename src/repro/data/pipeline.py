"""Training data pipeline staged through Pilot-Data tiers.

The paper's data-workflow story (§3.1 Fig. 3): raw data in cold storage,
pre-processed shards staged to warm storage, batches staged into memory for
the compute phase. Here: a deterministic synthetic corpus (Zipf-ish token
stream with local structure so the loss actually falls) is materialized as
file-tier DataUnit shards; the pipeline stages shard-by-shard into the host
tier, slices batches, and hands device-ready arrays to the trainer with a
background prefetch thread (overlap stage-in with compute, the paper's
'ensure data is available before the CU starts').
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.data import DataUnit
from repro.core.memory import StorageBackend, make_backend


def synthesize_corpus(vocab_size: int, num_tokens: int, seed: int = 0,
                      order: int = 2) -> np.ndarray:
    """Synthetic corpus with learnable bigram structure (vectorized)."""
    rng = np.random.default_rng(seed)
    # Zipf-ish unigram over a capped alphabet for speed
    v_eff = min(vocab_size, 32768)
    ranks = np.arange(1, v_eff + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    base = rng.choice(v_eff, size=num_tokens, p=probs).astype(np.int32)
    # inject bigram determinism: token[i] = f(token[i-1]) on a fraction of
    # positions, giving the model something to learn beyond unigram entropy
    mask = rng.random(num_tokens) < 0.65
    out = base.copy()
    # two passes so mapped tokens chain (strengthens the bigram signal)
    for _ in range(2):
        mapped = (np.roll(out, 1) * 31 + 7) % v_eff
        out = np.where(mask, mapped, out).astype(np.int32)
    return out


def corpus_data_unit(name: str, cfg: ModelConfig, num_tokens: int,
                     backends: Dict[str, StorageBackend],
                     num_shards: int = 8, seed: int = 0,
                     tier: str = "file", tier_manager=None) -> DataUnit:
    corpus = synthesize_corpus(cfg.vocab_size, num_tokens, seed)
    return DataUnit.from_array(name, corpus, num_shards, backends, tier=tier,
                               tier_manager=tier_manager)


class BatchPipeline:
    """Iterator of train batches with background stage-in + prefetch.

    When the DataUnit is attached to a TierManager, shard stage-in rides
    the manager's thread-pool stager via depth-`stage_depth` prefetch
    hints, so training input staging shares the same tier budgets, heat
    accounting, and eviction policy as analytics DataUnits (one budget
    model across the system); an unmanaged DU degrades to plain reads.

    With `pilot` set (and the DU bound to a PilotDataService) shard reads
    and prefetches route through THAT pilot's own TierManager instead:
    the training input stream rides the pilot's per-pilot budget and
    replica residency, so a trainer pinned to one pilot stages against
    the memory it actually owns rather than a global pool."""

    def __init__(self, du: DataUnit, cfg: ModelConfig, batch: int,
                 seq_len: int, prefetch: int = 2, seed: int = 0,
                 stage_depth: int = 2, stage_tier: str = "host",
                 pilot=None):
        self.du = du
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.stage_depth = stage_depth
        self.stage_tier = stage_tier
        self.pilot = pilot
        self.tokens_per_batch = batch * (seq_len + 1)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._rng = np.random.default_rng(seed)
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        shard_idx = 0
        buf = np.empty((0,), np.int32)
        while not self._stop.is_set():
            while buf.size < self.tokens_per_batch:
                if self._stop.is_set():
                    return      # bail between shard reads, not only between
                #                 batches, so close() joins promptly even on
                #                 slow (throttled) tiers
                # keep the next shards in flight on the shared stager while
                # this one is sliced (budget-refused stages are harmless)
                self.du.prefetch_window(shard_idx + 1, self.stage_depth,
                                        self.stage_tier, wrap=True,
                                        pilot=self.pilot)
                part = np.asarray(
                    self.du.partition(shard_idx % self.du.num_partitions,
                                      pilot=self.pilot))
                shard_idx += 1
                buf = np.concatenate([buf, part.reshape(-1)])
            take, buf = (buf[:self.tokens_per_batch],
                         buf[self.tokens_per_batch:])
            arr = take.reshape(self.batch, self.seq_len + 1)
            batch = {"tokens": arr[:, :-1].astype(np.int32),
                     "labels": arr[:, 1:].astype(np.int32)}
            self._add_modalities(batch)
            # retry until the consumer takes it: a slow train step must
            # stall the stream, not silently drop this batch's tokens
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=1.0)
                    break
                except queue.Full:
                    continue

    def _add_modalities(self, batch):
        cfg = self.cfg
        if cfg.vision_tokens:
            batch["patch_embeds"] = self._rng.normal(
                0, 0.5, size=(self.batch, cfg.vision_tokens,
                              cfg.vision_embed_dim)).astype(np.float32)
        if cfg.encoder_layers:
            batch["frames"] = self._rng.normal(
                0, 0.5, size=(self.batch, cfg.encoder_seq_len,
                              cfg.d_model)).astype(np.float32)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        """Stop the producer deterministically (no thread leaks across
        tests): signal, unblock any pending put, and join. The join bound
        covers one in-flight shard read (simulated-profile sleeps are
        capped at 5 s)."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=10.0)
