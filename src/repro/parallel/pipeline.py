"""Pipeline parallelism: GPipe-style microbatch pipeline over a "pipe" axis.

shard_map-manual over the pipe axis: each stage holds L/P layers (the
stacked layer params are sharded on their leading "layers" dim), activations
move stage-to-stage with jax.lax.ppermute. The schedule runs M + P - 1
ticks for M microbatches (fill + steady state + drain); bubble fraction
(P-1)/(M+P-1) — reported by ``bubble_fraction`` so configs can pick M.

This is the TPU-idiomatic translation of send/recv pipelines: ppermute is
a collective-permute on the ICI torus, overlapped with the stage compute by
XLA's latency-hiding scheduler.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import compat_shard_map


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_forward(layer_fn: Callable, stage_params, x_micro: jax.Array,
                     mesh: Mesh, axis: str = "pipe"):
    """Run a microbatched pipeline forward.

    layer_fn(params_slice, x) -> x : applies ONE STAGE (its layer block).
    stage_params: pytree with leading dim = num_stages (sharded over axis).
    x_micro: (M, mb, ...) microbatched input, replicated over the pipe axis.
    Returns (M, mb, ...) outputs (as produced by the last stage).
    """
    p = mesh.shape[axis]
    m = x_micro.shape[0]

    def stage_prog(params_stage, xs):
        # params_stage: this stage's params (leading dim 1); xs: (M, mb, ...)
        params_stage = jax.tree.map(lambda t: t[0], params_stage)
        sid = jax.lax.axis_index(axis)
        ticks = m + p - 1
        buf = jnp.zeros_like(xs[0])                     # current activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid)
            inject = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(sid == 0, xs[inject], buf)
            y = layer_fn(params_stage, x_in)
            # valid window: stage s works on tick t iff s <= t < s + m
            valid = (sid <= t) & (t < sid + m)
            y = jnp.where(valid, y, buf)
            # last stage records its finished microbatch
            out_idx = jnp.clip(t - (p - 1), 0, m - 1)
            record = (sid == p - 1) & (t >= p - 1)
            outs = jax.lax.cond(
                record,
                lambda o: o.at[out_idx].set(y),
                lambda o: o, outs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % p) for i in range(p)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage's buffer is real; psum of the masked buffers
        # broadcasts it (one collective, replicated result over pipe)
        outs = jax.lax.psum(jnp.where(sid == p - 1, outs, 0), axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = compat_shard_map(stage_prog, mesh,
                          in_specs=(spec_params, P()), out_specs=P())
    return fn(stage_params, x_micro)
