"""Logical-axis sharding rules (t5x-style) with divisibility-aware fallback.

Every parameter / activation declares *logical* axis names; a rule table maps
them to mesh axes. ``resolve_pspec`` drops mesh axes that do not divide the
dimension (e.g. kv_heads=8 over a 16-way "model" axis) and never assigns the
same mesh axis to two dims of one tensor — later dims fall back to the next
alternative rule. This keeps one model definition valid across every
(arch x shape x mesh) cell; the §Perf hillclimb edits rules, not models.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
# Each logical axis may have several alternatives, tried in order.
Rule = Tuple[str, MeshAxes]


DEFAULT_RULES: Tuple[Rule, ...] = (
    # --- activations ---
    ("batch", ("pod", "data")),
    ("seq", None),                  # query sequence (train/prefill)
    ("kv_seq", "model"),            # decode KV-cache sequence (flash-decoding style)
    ("long_seq", ("data", "model")),  # 500k decode cache, batch=1
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_kv_heads", "model"),
    ("act_head_dim", None),
    ("act_mlp", "model"),
    ("act_vocab", "model"),
    ("act_expert", "model"),
    ("act_ssm_inner", "model"),
    ("moe_group", ("pod", "data")),   # MoE dispatch-buffer group dim (scatter side)
    ("moe_group2", ("pod", "data")),  # ...compute side (EP-2D overrides to None)
    ("act_expert2", "model"),         # ...compute side (EP-2D: ("model","data"))
    ("moe_cap", None),                # MoE capacity dim

    # --- params ---
    ("vocab", "model"),
    ("embed", "data"),              # FSDP: shard params' d_model dim over data
    ("heads", "model"),
    ("kv_heads", "model"),          # falls back (replicate) when kv < |model|
    ("head_dim", None),
    ("mlp", "model"),
    ("expert", "model"),
    ("expert_embed", "data"),
    ("expert_mlp", "model"),        # used when "expert" could not take the axis
    ("ssm_inner", "model"),
    ("ssm_state", None),
    ("dt_rank", None),
    ("conv_k", None),
    ("mla_rank", None),
    ("layers", None),
    ("stack", None),
)


def _as_tuple(axes: MeshAxes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


class AxisRules:
    """Ordered logical->mesh mapping. Later entries with the same logical name
    act as fallback alternatives."""

    def __init__(self, rules: Sequence[Rule] = DEFAULT_RULES):
        self.rules: Tuple[Rule, ...] = tuple(rules)

    def alternatives(self, logical: str) -> Tuple[MeshAxes, ...]:
        alts = tuple(axes for name, axes in self.rules if name == logical)
        return alts if alts else (None,)

    def override(self, *new_rules: Rule) -> "AxisRules":
        """New rules take priority (prepended)."""
        return AxisRules(tuple(new_rules) + self.rules)

    def replacing(self, logical: str, axes: MeshAxes) -> "AxisRules":
        kept = tuple(r for r in self.rules if r[0] != logical)
        return AxisRules(((logical, axes),) + kept)


_ctx = threading.local()


class sharding_context:
    """Install (mesh, rules) for with_logical_constraint inside model code."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
        self.mesh = mesh
        self.rules = rules or AxisRules()

    def __enter__(self):
        self._prev = getattr(_ctx, "cur", None)
        _ctx.cur = self
        return self

    def __exit__(self, *exc):
        _ctx.cur = self._prev


def current_context() -> Optional["sharding_context"]:
    return getattr(_ctx, "cur", None)


def resolve_pspec(
    logical_dims: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: AxisRules,
) -> P:
    """Build a PartitionSpec, honoring divisibility and no-axis-reuse."""
    assert len(logical_dims) == len(shape), (logical_dims, shape)
    used: set = set()
    out = []
    axis_sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh
    for logical, dim in zip(logical_dims, shape):
        chosen: MeshAxes = None
        if logical is not None:
            for alt in rules.alternatives(logical):
                axes = tuple(a for a in _as_tuple(alt)
                             if a in axis_sizes and a not in used)
                if not axes:
                    continue
                total = int(np.prod([axis_sizes[a] for a in axes]))
                if dim % total == 0:
                    chosen = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
                    break
                # try a prefix of the axis tuple (e.g. ("data","model")->("data",))
                for k in range(len(axes) - 1, 0, -1):
                    sub = axes[:k]
                    total = int(np.prod([axis_sizes[a] for a in sub]))
                    if dim % total == 0:
                        chosen = sub if len(sub) > 1 else sub[0]
                        used.update(sub)
                        break
                if chosen is not None:
                    break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    logical_dims: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: AxisRules,
) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(logical_dims, shape, mesh, rules))


def with_logical_constraint(x: jax.Array, *logical_dims: Optional[str]):
    """Sharding-constrain an intermediate by logical axis names.

    No-op outside a sharding_context (keeps smoke tests mesh-free).
    """
    ctx = current_context()
    if ctx is None or ctx.mesh is None:
        return x
    spec = resolve_pspec(logical_dims, x.shape, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def logical_sharding(logical_dims, shape) -> Optional[NamedSharding]:
    ctx = current_context()
    if ctx is None or ctx.mesh is None:
        return None
    return named_sharding(logical_dims, shape, ctx.mesh, ctx.rules)
