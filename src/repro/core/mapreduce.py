"""MapReduce over in-memory Data-Units (Pilot-Data Memory §3.3).

Paper: "we extend the DU interface to provide a higher-level MapReduce-based
API for expressing transformations on the data ... The runtime system
generates the necessary application tasks (Compute-Units) and runs these in
parallel considering data locality."

Execution paths (the paper's backend-adaptor mechanism):
  file/object/host tiers -> one CU per partition through the
      ComputeDataManager (the paper's file/Redis backends: data staged to
      the worker per task);
  device tier           -> partitions already HBM-resident; map runs as a
      jitted kernel per partition WITHOUT restaging, and the executable is
      warm in the pilot's jit cache (the paper's Spark backend: this is
      where the 212x comes from).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
from typing import Any, Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.core.data import DataUnit
from repro.core.manager import ComputeDataManager
from repro.core.pilot import ComputeUnitDescription, PilotCompute


def map_reduce(du: DataUnit, map_fn: Callable, reduce_fn: Callable,
               manager: Optional[ComputeDataManager] = None,
               pilot: Optional[PilotCompute] = None,
               extra_args: tuple = (),
               jit_map: bool = True) -> Any:
    """map_fn(partition, *extra_args) -> value; reduce_fn(a, b) -> value.

    reduce_fn must be associative+commutative (tree reduction order).
    """
    if du.tier == "device":
        return _map_reduce_device(du, map_fn, reduce_fn, pilot, extra_args,
                                  jit_map)
    # the compute kernel is identical across tiers (paper: same CU, different
    # backend); only staging differs — so jit the map here too
    mfn = _jit_cached(map_fn) if jit_map else map_fn
    if manager is None:
        # local fallback: still partition-parallel in semantics; on managed
        # cold tiers the background stager pulls partition i+1 toward host
        # while i computes, so staging overlaps the map instead of gating it
        vals = []
        for i in range(du.num_partitions):
            du.prefetch(i + 1)
            vals.append(mfn(jnp.asarray(du.partition(i)), *extra_args))
        return functools.reduce(reduce_fn, vals)
    cus = []

    def _task(idx):
        du.prefetch(idx + 1)
        return mfn(jnp.asarray(du.partition(idx)), *extra_args)

    for i in range(du.num_partitions):
        cus.append(manager.submit(ComputeUnitDescription(
            fn=lambda idx=i: _task(idx),
            input_data=(du,), affinity=du.affinity,
            name=f"{du.name}-map{i:04d}")))
    vals = [cu.result() for cu in cus]
    return functools.reduce(reduce_fn, vals)


_JIT_CACHE: dict = {}


def _jit_cached(fn):
    if fn not in _JIT_CACHE:
        _JIT_CACHE[fn] = jax.jit(fn)
    return _JIT_CACHE[fn]


def _map_reduce_device(du: DataUnit, map_fn, reduce_fn, pilot, extra_args,
                       jit_map: bool):
    """Device-tier path: no host restaging; jitted map; warm-cache reuse."""
    if jit_map:
        if pilot is not None:
            jitted = pilot.jit_cached(("map", map_fn), lambda: jax.jit(map_fn))
        else:
            jitted = jax.jit(map_fn)
    else:
        jitted = map_fn
    vals: List[Any] = []
    for i in range(du.num_partitions):
        # under a budgeted device tier some partitions sit one level colder;
        # start their promotion while the current partition computes
        du.prefetch(i + 1, "device")
        vals.append(jitted(du.partition_device(i), *extra_args))
    # tree reduce (log depth; on real pods this maps to collective schedule)
    while len(vals) > 1:
        nxt = []
        for j in range(0, len(vals) - 1, 2):
            nxt.append(reduce_fn(vals[j], vals[j + 1]))
        if len(vals) % 2:
            nxt.append(vals[-1])
        vals = nxt
    return vals[0]
