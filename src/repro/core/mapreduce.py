"""MapReduce over in-memory Data-Units (Pilot-Data Memory §3.3).

Paper: "we extend the DU interface to provide a higher-level MapReduce-based
API for expressing transformations on the data ... The runtime system
generates the necessary application tasks (Compute-Units) and runs these in
parallel considering data locality."

Execution paths (the paper's backend-adaptor mechanism):
  file/object/host tiers -> Compute-Units through the ComputeDataManager
      (the paper's file/Redis backends: data staged to the worker per task);
  device tier           -> partitions already HBM-resident; map runs as a
      jitted kernel per partition WITHOUT restaging, and the executable is
      warm in the pilot's jit cache (the paper's Spark backend: this is
      where the 212x comes from).

Pipelined engine (default): instead of the PR 1 "prefetch partition i+1"
hint, every path runs a depth-k double-buffered loop — while partition i is
being mapped, up to `prefetch_depth` later partitions are in flight on the
TierManager's thread-pool stager, and each mapped value is folded into a
running partial immediately (fused tree-combining).  The fold keeps exactly
one partial live per worker, so under a budgeted device tier the reduce
phase moves one partial per pilot instead of one value per partition, and
cold-tier stage-in overlaps the map instead of gating it.  On the managed
path partitions are grouped per pilot: one Compute-Unit per pilot maps+
combines its slice, and the driver reduces the per-pilot partials.
`pipeline=False` restores the PR 1 sequential behavior (one CU per
partition, i+1 prefetch, post-hoc reduction) — kept as the benchmark
baseline.

Adaptive prefetch depth (default, `prefetch_depth=None`): the depth is
derived per worker from measured stage-vs-compute times — an EWMA seeded
from the TierManager's TierProfile restage cost and updated with observed
prefetch waits and per-partition compute times — so staging-bound scans
deepen the pipeline while compute-bound scans stop issuing useless
stages.  Passing `prefetch_depth=k` remains an explicit fixed override.

Replica-aware grouping (DataUnits bound to a PilotDataService): each
partition group is routed to the pilot already holding (most of) its
partitions, unheld partitions are balanced across pilots, and the group's
leading partitions are replicated toward the chosen pilot before the CU
starts (pre-binding stage-in).  Each pilot's fold then reads through ITS
OWN TierManager, so a 2-pilot run splits a 2x-over-budget working set
across two device budgets instead of thrashing one.
"""
from __future__ import annotations

import functools
import itertools
import math
import time

import jax.numpy as jnp
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.data import DataUnit
from repro.core.manager import ComputeDataManager
from repro.core.pilot import ComputeUnitDescription, PilotCompute
from repro.core.supervisor import RETRY_BACKOFF

# upper bound on waiting for one in-flight prefetch before falling back to
# reading the partition wherever it currently resides
_PREFETCH_WAIT_S = 120.0
# pre-binding stage-in width when the depth itself is adaptive
_DEFAULT_PREBIND = 2


class _AdaptiveDepth:
    """EWMA-derived pipeline depth: ceil(stage_time / compute_time).

    Staging-bound scans are wall-clock-bounded by staging/depth, so the
    depth must cover the stage-to-compute ratio; compute-bound scans need
    only one look-ahead.  The stage estimate is the max of a static seed
    (the TierProfile-derived restage cost of a representative partition)
    and an EWMA of *observed* prefetch waits, so an optimistic profile is
    corrected by measurement; compute is an EWMA of mapped-partition
    times.  Before the first observation the PR 2 default (2) applies.
    """

    def __init__(self, seed_stage: float = 0.0, max_depth: int = 8,
                 alpha: float = 0.4):
        self.max_depth = max(1, int(max_depth))
        self.alpha = alpha
        self._seed = max(0.0, seed_stage)
        self._wait = 0.0
        self._compute = 0.0
        self._n = 0

    def observe(self, compute_s: float, wait_s: float = 0.0) -> None:
        a = self.alpha
        if self._n == 0:
            self._compute, self._wait = compute_s, wait_s
        else:
            self._compute = (1 - a) * self._compute + a * compute_s
            self._wait = (1 - a) * self._wait + a * wait_s
        self._n += 1

    @property
    def depth(self) -> int:
        if self._n == 0 or self._compute <= 1e-9:
            return min(2, self.max_depth)
        stage = max(self._seed, self._wait)
        return max(1, min(self.max_depth,
                          math.ceil(stage / self._compute)))


def _depth_controller(du: DataUnit, prefetch_depth: Optional[int],
                      indices: Sequence[int],
                      tier_manager=None,
                      target_tier: str = "host"
                      ) -> Union[int, "_AdaptiveDepth"]:
    """An explicit depth passes through; None builds the adaptive
    controller, seeded from the stage-in cost of the group's leading
    partitions in the manager the reads actually go through — the group
    pilot's own TierManager on the replica path, else the DU's home
    manager (0 => purely observation-driven).

    The seed is the WORST promote_cost over the first few partitions
    toward `target_tier`, billed at each partition's *actual* tier, so a
    group whose leading partitions were spilled to the slow checkpoint
    tier seeds a deep pipeline (its restores are bandwidth-bound on the
    persistent store) while an all-host group seeds a shallow one."""
    if prefetch_depth is not None:
        return max(1, int(prefetch_depth))
    seed = 0.0
    if indices:
        for tm in (tier_manager, du.tier_manager):
            if tm is None:
                continue
            costs = []
            for i in indices[:4]:
                try:
                    costs.append(tm.promote_cost(du._key(i), target_tier))
                except KeyError:
                    continue
            if costs:
                seed = max(costs)
                break
    return _AdaptiveDepth(seed_stage=seed)


def map_reduce(du: DataUnit, map_fn: Callable, reduce_fn: Callable,
               manager: Optional[ComputeDataManager] = None,
               pilot: Optional[PilotCompute] = None,
               extra_args: tuple = (),
               jit_map: bool = True,
               prefetch_depth: Optional[int] = None,
               pipeline: bool = True,
               retries: int = 1,
               prebind_wait_s: Optional[float] = None) -> Any:
    """map_fn(partition, *extra_args) -> value; reduce_fn(a, b) -> value.

    reduce_fn must be associative+commutative (combine order is not fixed:
    the pipelined engine folds left per worker and reduces partials across
    workers; the legacy path tree-reduces).  prefetch_depth=None sizes the
    pipeline adaptively from measured stage/compute times; an int fixes it.

    `manager` may also be a PilotSession (the v2 façade) — its scheduler
    is unwrapped, so `map_reduce(du, f, r, manager=session)` and
    `session.map_reduce(du, f, r)` are the same call.

    retries (managed pipelined path): when a group's Compute-Unit fails —
    typically its pilot died mid-run — the group's partitions are re-bound
    onto the surviving pilots and re-run, up to `retries` times.  The new
    pilots' reads pull the partitions back through the PilotDataService
    fetch path, whose last resort is the durable checkpoint home, so a
    pilot failure costs a lazy restore instead of the whole job (0
    disables; partial results from healthy groups are never recomputed).

    prebind_wait_s (managed paths): per-CU override of the pilot's
    pre-binding stage-in wait bound, threaded onto every Compute-Unit
    map_reduce submits internally (None = each pilot's configured
    default) — a job scanning cold data once can cap how long a wedged
    stage may delay its groups without re-describing the pilots.
    """
    if manager is not None and not isinstance(manager, ComputeDataManager):
        # a PilotSession (or anything façade-shaped) stands in for its
        # scheduler; duck-typed to keep session.py the only importer of
        # the façade layer
        inner = getattr(manager, "manager", None)
        if isinstance(inner, ComputeDataManager):
            manager = inner
        else:
            raise TypeError(f"map_reduce: manager must be a "
                            f"ComputeDataManager or PilotSession, got "
                            f"{type(manager).__name__}")
    if du.tier == "device":
        return _map_reduce_device(du, map_fn, reduce_fn, pilot, extra_args,
                                  jit_map, prefetch_depth, pipeline)
    # the compute kernel is identical across tiers (paper: same CU, different
    # backend); only staging differs — so jit the map here too
    mfn = _jit_cached(map_fn) if jit_map else map_fn

    def compute(i):
        # zero-copy stage-in (PR 8): partition_buf hands back the serving
        # tier's read-only view; jnp.asarray consumes it directly, so the
        # only copy in the pipeline is the host->device transfer itself
        return mfn(jnp.asarray(du.partition_buf(i).view()), *extra_args)

    if manager is None:
        if pipeline:
            idxs = list(range(du.num_partitions))
            return _pipeline_fold(du, idxs, compute, reduce_fn,
                                  _depth_controller(du, prefetch_depth, idxs),
                                  "host")
        # legacy sequential path: i+1 hint, post-hoc reduction
        vals = []
        for i in range(du.num_partitions):
            du.prefetch(i + 1)
            vals.append(compute(i))
        return functools.reduce(reduce_fn, vals)

    if pipeline:
        # fused partial reduction per pilot: one CU per partition group
        # maps + combines locally; only the per-pilot partials cross back
        # to the driver (cuts reduce-phase data motion)
        prebind = (prefetch_depth if isinstance(prefetch_depth, int)
                   else _DEFAULT_PREBIND)
        group_no = itertools.count()

        def _submit_replica(gi, grp_pilot, idxs):
            # distributed Pilot-Data: the group is bound to the pilot
            # holding its replicas and reads through THAT pilot's tiers
            def _fold(idxs=idxs, p=grp_pilot):
                comp = (lambda i:
                        mfn(du.partition_device(i, pilot=p), *extra_args))
                return _pipeline_fold(
                    du, idxs, comp, reduce_fn,
                    _depth_controller(du, prefetch_depth, idxs,
                                      tier_manager=p.tier_manager,
                                      target_tier="device"),
                    "device", pilot=p)
            return manager.submit(ComputeUnitDescription(
                fn=_fold, input_data=(du,), affinity=du.affinity,
                prefetch_parts=tuple(idxs[:prebind]),
                prebind_wait_s=prebind_wait_s,
                name=f"{du.name}-mapg{gi:03d}"), pilot=grp_pilot)

        def _submit_home(gi, idxs, exclude):
            return manager.submit(ComputeUnitDescription(
                fn=lambda idxs=idxs: _pipeline_fold(
                    du, idxs, compute, reduce_fn,
                    _depth_controller(du, prefetch_depth, idxs), "host"),
                input_data=(du,), affinity=du.affinity,
                prefetch_parts=tuple(idxs[:prebind]),
                prebind_wait_s=prebind_wait_s,
                name=f"{du.name}-mapg{gi:03d}"), exclude=exclude)

        def _submit_groups(indices, exclude):
            """One (cu, idxs) job per group over the CURRENTLY healthy
            pilots (minus `exclude`), replica-aware when possible."""
            groups = _replica_groups(du, manager, indices=indices,
                                     exclude=exclude)
            if groups is not None:
                return [(_submit_replica(next(group_no), p, idxs), idxs)
                        for p, idxs in groups]
            return [(_submit_home(next(group_no), idxs, exclude), idxs)
                    for idxs in _partition_groups(du, manager,
                                                  indices=indices)]

        jobs = _submit_groups(None, frozenset())
        partials: List[Any] = []
        last_error: Optional[BaseException] = None
        attempts = max(0, int(retries))
        for attempt in range(attempts + 1):
            failed_idxs: List[int] = []
            failed_pilots: set = set()
            for cu, idxs in jobs:
                try:
                    partials.append(cu.result())
                except Exception as e:  # noqa: BLE001 - retried below
                    last_error = e
                    failed_idxs.extend(idxs)
                    if cu.pilot_id:
                        failed_pilots.add(cu.pilot_id)
            if not failed_idxs:
                break
            if attempt == attempts:
                raise last_error
            # recovery path: re-bind only the failed partitions onto the
            # surviving pilots; their reads pull the data back through the
            # PilotDataService fetch chain (live replicas, then the
            # durable checkpoint home), so a mid-run pilot death costs a
            # lazy restore, not the job.  Back off first (bounded, with
            # jitter): re-submitting the instant a pilot died races the
            # supervisor's quarantine and stampedes the survivors.
            RETRY_BACKOFF.sleep(attempt)
            healthy = {p.id for p in manager.eligible_pilots()}
            if not healthy:
                raise last_error
            exclude = (frozenset(failed_pilots) if healthy - failed_pilots
                       else frozenset())    # all failed: reset, like
            #                                 result_with_retry
            jobs = _submit_groups(sorted(failed_idxs), exclude)
        return functools.reduce(reduce_fn, partials)

    def _task(idx):
        du.prefetch(idx + 1)
        return compute(idx)

    # legacy one-CU-per-partition path, routed through the batched task
    # engine: the N map tasks are scored in ONE policy pass and run on
    # the pilots' resident worker pools instead of paying N submit()
    # round-trips (results still reduce in partition order)
    batch = manager.submit_tasks(
        [ComputeUnitDescription(
            fn=lambda idx=i: _task(idx),
            input_data=(du,), affinity=du.affinity,
            prebind_wait_s=prebind_wait_s,
            name=f"{du.name}-map{i:04d}")
         for i in range(du.num_partitions)],
        retries=max(0, int(retries)))
    return functools.reduce(reduce_fn, batch.results())


def _pipeline_fold(du: DataUnit, indices, compute: Callable,
                   reduce_fn: Callable,
                   depth: Union[int, _AdaptiveDepth], tier: str,
                   pilot: Optional[PilotCompute] = None) -> Any:
    """Depth-k double-buffered map+combine over `indices`.

    Keeps up to `depth` stage-ins in flight on the background stager while
    the current partition computes, waits for partition i's own stage (if
    one was issued) so the read hits the warm tier, and folds each mapped
    value into a running partial so at most one partial plus the current
    partition are live at any time.  With `pilot` set, prefetches and
    reads target that pilot's own tiers (per-pilot replica residency).
    An _AdaptiveDepth instance re-sizes the look-ahead every iteration
    from the measured stage-vs-compute ratio.
    """
    indices = list(indices)
    adaptive = isinstance(depth, _AdaptiveDepth)
    inflight: dict = {}
    acc = None
    for pos, i in enumerate(indices):
        d = depth.depth if adaptive else max(1, int(depth))
        for j in indices[pos + 1: pos + 1 + d]:
            if j not in inflight:
                inflight[j] = du.prefetch(j, tier, pilot=pilot)
        fut = inflight.pop(i, None)
        wait_s = 0.0
        if fut is not None:
            t0 = time.perf_counter()
            try:
                fut.result(timeout=_PREFETCH_WAIT_S)
            except Exception:   # noqa: BLE001
                pass    # refused/raced stage: the read finds the partition
            wait_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        val = compute(i)
        if adaptive:
            depth.observe(compute_s=time.perf_counter() - t0, wait_s=wait_s)
        acc = val if acc is None else reduce_fn(acc, val)
    return acc


def _partition_groups(du: DataUnit, manager: ComputeDataManager,
                      indices: Optional[Sequence[int]] = None
                      ) -> List[List[int]]:
    """Contiguous partition slices, one per healthy pilot (>=1); `indices`
    restricts the split to a subset (the retry path's failed residue)."""
    idx = (list(range(du.num_partitions)) if indices is None
           else list(indices))
    n_workers = max(1, len(manager.eligible_pilots()))
    n_groups = max(1, min(len(idx), n_workers))
    bounds = np.linspace(0, len(idx), n_groups + 1).astype(int)
    return [idx[bounds[g]:bounds[g + 1]]
            for g in range(n_groups) if bounds[g] < bounds[g + 1]]


def _replica_groups(du: DataUnit, manager: ComputeDataManager,
                    indices: Optional[Sequence[int]] = None,
                    exclude: frozenset = frozenset()
                    ) -> Optional[List[Tuple[PilotCompute, List[int]]]]:
    """Replica-aware partition->pilot assignment, or None when the DU is
    not bound to a PilotDataService (or no healthy pilot participates in
    it — the contiguous fallback then applies).

    Each partition sticks to the pilot already holding its replica at the
    hottest tier (so iterated scans keep hitting warm per-pilot memory);
    partitions no pilot holds go to the least-loaded pilots, keeping the
    split balanced and deterministic.  `indices` restricts the assignment
    to a subset and `exclude` removes pilots (both used by the failure
    retry, which re-binds only the failed residue onto survivors).
    """
    pds = getattr(du, "pilot_data_service", None)
    if pds is None:
        return None
    pilots = [p for p in manager.eligible_pilots(exclude)
              if getattr(p, "tier_manager", None) is not None
              and pds.knows(p.id)]
    if not pilots:
        return None
    by_id = {p.id: p for p in pilots}
    assign: dict = {p.id: [] for p in pilots}
    unheld: List[int] = []
    for i in (range(du.num_partitions) if indices is None else indices):
        best = pds.best_pilot(du._key(i), list(assign))
        if best is not None:
            assign[best].append(i)
        else:
            unheld.append(i)
    for i in unheld:
        target = min(assign, key=lambda pid: len(assign[pid]))
        assign[target].append(i)
    return [(by_id[pid], idxs) for pid, idxs in assign.items() if idxs]


_JIT_CACHE: dict = {}


def _jit_cached(fn):
    if fn not in _JIT_CACHE:
        _JIT_CACHE[fn] = jax.jit(fn)
    return _JIT_CACHE[fn]


def _map_reduce_device(du: DataUnit, map_fn, reduce_fn, pilot, extra_args,
                       jit_map: bool, prefetch_depth: Optional[int],
                       pipeline: bool):
    """Device-tier path: no host restaging; jitted map; warm-cache reuse."""
    if jit_map:
        if pilot is not None:
            jitted = pilot.jit_cached(("map", map_fn), lambda: jax.jit(map_fn))
        else:
            jitted = _jit_cached(map_fn)
    else:
        jitted = map_fn
    if pipeline:
        # fused combine keeps one partial in HBM instead of num_partitions
        # mapped values awaiting the tree reduce
        idxs = list(range(du.num_partitions))
        return _pipeline_fold(
            du, idxs,
            lambda i: jitted(du.partition_device(i), *extra_args),
            reduce_fn,
            _depth_controller(du, prefetch_depth, idxs,
                              target_tier="device"), "device")
    vals: List[Any] = []
    for i in range(du.num_partitions):
        # under a budgeted device tier some partitions sit one level colder;
        # start their promotion while the current partition computes
        du.prefetch(i + 1, "device")
        vals.append(jitted(du.partition_device(i), *extra_args))
    # tree reduce (log depth; on real pods this maps to collective schedule)
    while len(vals) > 1:
        nxt = []
        for j in range(0, len(vals) - 1, 2):
            nxt.append(reduce_fn(vals[j], vals[j + 1]))
        if len(vals) % 2:
            nxt.append(vals[-1])
        vals = nxt
    return vals[0]
