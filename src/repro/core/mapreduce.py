"""MapReduce over in-memory Data-Units (Pilot-Data Memory §3.3).

Paper: "we extend the DU interface to provide a higher-level MapReduce-based
API for expressing transformations on the data ... The runtime system
generates the necessary application tasks (Compute-Units) and runs these in
parallel considering data locality."

Execution paths (the paper's backend-adaptor mechanism):
  file/object/host tiers -> Compute-Units through the ComputeDataManager
      (the paper's file/Redis backends: data staged to the worker per task);
  device tier           -> partitions already HBM-resident; map runs as a
      jitted kernel per partition WITHOUT restaging, and the executable is
      warm in the pilot's jit cache (the paper's Spark backend: this is
      where the 212x comes from).

Pipelined engine (default): instead of the PR 1 "prefetch partition i+1"
hint, every path runs a depth-k double-buffered loop — while partition i is
being mapped, up to `prefetch_depth` later partitions are in flight on the
TierManager's thread-pool stager, and each mapped value is folded into a
running partial immediately (fused tree-combining).  The fold keeps exactly
one partial live per worker, so under a budgeted device tier the reduce
phase moves one partial per pilot instead of one value per partition, and
cold-tier stage-in overlaps the map instead of gating it.  On the managed
path partitions are grouped per pilot: one Compute-Unit per pilot maps+
combines its contiguous slice, and the driver reduces the per-pilot
partials.  `pipeline=False` restores the PR 1 sequential behavior (one CU
per partition, i+1 prefetch, post-hoc reduction) — kept as the benchmark
baseline.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
from typing import Any, Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.core.data import DataUnit
from repro.core.manager import ComputeDataManager
from repro.core.pilot import ComputeUnitDescription, PilotCompute

# upper bound on waiting for one in-flight prefetch before falling back to
# reading the partition wherever it currently resides
_PREFETCH_WAIT_S = 120.0


def map_reduce(du: DataUnit, map_fn: Callable, reduce_fn: Callable,
               manager: Optional[ComputeDataManager] = None,
               pilot: Optional[PilotCompute] = None,
               extra_args: tuple = (),
               jit_map: bool = True,
               prefetch_depth: int = 2,
               pipeline: bool = True) -> Any:
    """map_fn(partition, *extra_args) -> value; reduce_fn(a, b) -> value.

    reduce_fn must be associative+commutative (combine order is not fixed:
    the pipelined engine folds left per worker and reduces partials across
    workers; the legacy path tree-reduces).
    """
    if du.tier == "device":
        return _map_reduce_device(du, map_fn, reduce_fn, pilot, extra_args,
                                  jit_map, prefetch_depth, pipeline)
    # the compute kernel is identical across tiers (paper: same CU, different
    # backend); only staging differs — so jit the map here too
    mfn = _jit_cached(map_fn) if jit_map else map_fn

    def compute(i):
        return mfn(jnp.asarray(du.partition(i)), *extra_args)

    if manager is None:
        if pipeline:
            return _pipeline_fold(du, range(du.num_partitions), compute,
                                  reduce_fn, prefetch_depth, "host")
        # legacy sequential path: i+1 hint, post-hoc reduction
        vals = []
        for i in range(du.num_partitions):
            du.prefetch(i + 1)
            vals.append(compute(i))
        return functools.reduce(reduce_fn, vals)

    if pipeline:
        # fused partial reduction per pilot: one CU per contiguous partition
        # group maps + combines locally; only the per-pilot partials cross
        # back to the driver (cuts reduce-phase data motion)
        cus = []
        for gi, idxs in enumerate(_partition_groups(du, manager)):
            cus.append(manager.submit(ComputeUnitDescription(
                fn=lambda idxs=idxs: _pipeline_fold(
                    du, idxs, compute, reduce_fn, prefetch_depth, "host"),
                input_data=(du,), affinity=du.affinity,
                prefetch_parts=tuple(idxs[:prefetch_depth]),
                name=f"{du.name}-mapg{gi:03d}")))
        return functools.reduce(reduce_fn, [cu.result() for cu in cus])

    cus = []

    def _task(idx):
        du.prefetch(idx + 1)
        return compute(idx)

    for i in range(du.num_partitions):
        cus.append(manager.submit(ComputeUnitDescription(
            fn=lambda idx=i: _task(idx),
            input_data=(du,), affinity=du.affinity,
            name=f"{du.name}-map{i:04d}")))
    vals = [cu.result() for cu in cus]
    return functools.reduce(reduce_fn, vals)


def _pipeline_fold(du: DataUnit, indices, compute: Callable,
                   reduce_fn: Callable, depth: int, tier: str) -> Any:
    """Depth-k double-buffered map+combine over `indices`.

    Keeps up to `depth` stage-ins in flight on the background stager while
    the current partition computes, waits for partition i's own stage (if
    one was issued) so the read hits the warm tier, and folds each mapped
    value into a running partial so at most one partial plus the current
    partition are live at any time.
    """
    indices = list(indices)
    depth = max(1, int(depth))
    inflight: dict = {}
    acc = None
    for pos, i in enumerate(indices):
        for j in indices[pos + 1: pos + 1 + depth]:
            if j not in inflight:
                inflight[j] = du.prefetch(j, tier)
        fut = inflight.pop(i, None)
        if fut is not None:
            try:
                fut.result(timeout=_PREFETCH_WAIT_S)
            except Exception:   # noqa: BLE001
                pass    # refused/raced stage: the read finds the partition
        val = compute(i)
        acc = val if acc is None else reduce_fn(acc, val)
    return acc


def _partition_groups(du: DataUnit,
                      manager: ComputeDataManager) -> List[List[int]]:
    """Contiguous partition slices, one per healthy pilot (>=1)."""
    n_workers = max(1, len(manager.service.healthy_pilots()))
    n_groups = max(1, min(du.num_partitions, n_workers))
    bounds = np.linspace(0, du.num_partitions, n_groups + 1).astype(int)
    return [list(range(bounds[g], bounds[g + 1]))
            for g in range(n_groups) if bounds[g] < bounds[g + 1]]


_JIT_CACHE: dict = {}


def _jit_cached(fn):
    if fn not in _JIT_CACHE:
        _JIT_CACHE[fn] = jax.jit(fn)
    return _JIT_CACHE[fn]


def _map_reduce_device(du: DataUnit, map_fn, reduce_fn, pilot, extra_args,
                       jit_map: bool, prefetch_depth: int, pipeline: bool):
    """Device-tier path: no host restaging; jitted map; warm-cache reuse."""
    if jit_map:
        if pilot is not None:
            jitted = pilot.jit_cached(("map", map_fn), lambda: jax.jit(map_fn))
        else:
            jitted = _jit_cached(map_fn)
    else:
        jitted = map_fn
    if pipeline:
        # fused combine keeps one partial in HBM instead of num_partitions
        # mapped values awaiting the tree reduce
        return _pipeline_fold(
            du, range(du.num_partitions),
            lambda i: jitted(du.partition_device(i), *extra_args),
            reduce_fn, prefetch_depth, "device")
    vals: List[Any] = []
    for i in range(du.num_partitions):
        # under a budgeted device tier some partitions sit one level colder;
        # start their promotion while the current partition computes
        du.prefetch(i + 1, "device")
        vals.append(jitted(du.partition_device(i), *extra_args))
    # tree reduce (log depth; on real pods this maps to collective schedule)
    while len(vals) > 1:
        nxt = []
        for j in range(0, len(vals) - 1, 2):
            nxt.append(reduce_fn(vals[j], vals[j + 1]))
        if len(vals) % 2:
            nxt.append(vals[-1])
        vals = nxt
    return vals[0]
