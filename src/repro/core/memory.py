"""Pilot-Data storage tiers: one DataUnit API over heterogeneous backends.

Paper mapping (§3.1/§3.3): the paper's pluggable Pilot-Data backends
(local disk / Lustre / HDFS / Redis / Spark-RDD) become storage *tiers* of a
TPU system:

  checkpoint — durable manifest-backed store (paper: Lustre/HDFS, the
               persistent anchor beneath the retained in-memory resources)
  file    — mmap'd .npy on disk            (paper: file backend, node-local)
  object  — file + simulated WAN latency   (paper: cloud object store, S3)
  host    — process-resident numpy         (paper: Redis in-memory store)
  device  — jax.Arrays resident in HBM     (paper: Spark executor memory)

The checkpoint tier is the only DURABLE one: its contents survive pilot
loss (`TierManager.lose_volatile`) and process restarts (an fsync'd JSON
manifest makes a reopened store self-describing).  Writes are asynchronous
(the repro.checkpoint.CheckpointManager write-behind pattern): `put`
buffers and returns, a writer thread lands bytes atomically
(tmp + rename), and reads of a still-pending key are served from the
buffer, so demotion into the slow tier never stalls the stager.  `flush`
drains the writer and fsyncs the manifest deterministically.

Backends expose a bandwidth/latency profile so benchmarks can reproduce the
paper's Stampede-disk vs Gordon-flash comparison (Fig. 7/8) on one box: the
simulated profiles throttle honestly (sleep for bytes/bw) and are clearly
labeled as simulations in benchmark output.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import jax
import numpy as np

from repro.core import codecs as _codecs
from repro.core.buf import (as_view, device_view, materialize,
                            zero_copy_enabled)

TIERS = ("checkpoint", "file", "object", "host", "device")

# tiers whose contents survive pilot loss (TierManager.lose_volatile) —
# everything else dies with the node that held it
DURABLE_TIERS = ("checkpoint",)


@dataclasses.dataclass(frozen=True)
class TierProfile:
    """Bandwidth/latency model for a simulated storage tier."""
    name: str
    read_bw: float = 0.0       # bytes/s; 0 = unthrottled (native speed)
    write_bw: float = 0.0
    latency: float = 0.0       # seconds per operation
    simulate: bool = False

    def charge(self, nbytes: int, write: bool) -> None:
        if not self.simulate:
            return
        bw = self.write_bw if write else self.read_bw
        t = self.latency + (nbytes / bw if bw else 0.0)
        if t > 0:
            time.sleep(min(t, 5.0))  # cap: benchmarks stay bounded


# Published-order-of-magnitude profiles for the Fig. 7/8 reproductions.
PROFILES: Dict[str, TierProfile] = {
    "stampede_disk": TierProfile("stampede_disk", 120e6, 90e6, 5e-3, True),
    "gordon_flash": TierProfile("gordon_flash", 800e6, 500e6, 1e-4, True),
    "lustre": TierProfile("lustre", 300e6, 200e6, 2e-3, True),
    "hdfs": TierProfile("hdfs", 250e6, 80e6, 8e-3, True),
    "object_store": TierProfile("object_store", 80e6, 40e6, 50e-3, True),
    "native": TierProfile("native"),
}

# Nominal read/write bandwidth (bytes/s) per tier when its profile runs
# unthrottled; cost-aware eviction (GDSF) uses these so restage costs stay
# ordered (file < object << host << device) even without simulated profiles.
DEFAULT_TIER_BANDWIDTH: Dict[str, float] = {
    "checkpoint": 120e6, "file": 200e6, "object": 80e6, "host": 10e9,
    "device": 60e9,
}


class StorageBackend:
    """One tier's put/get/delete over named partitions."""

    tier: str = "file"

    def __init__(self, profile: TierProfile = PROFILES["native"]):
        self.profile = profile

    def put(self, name: str, value: np.ndarray) -> None:
        raise NotImplementedError

    def get(self, name: str) -> np.ndarray:
        raise NotImplementedError

    def delete(self, name: str) -> None:
        raise NotImplementedError

    def exists(self, name: str) -> bool:
        raise NotImplementedError

    def nbytes(self, name: str) -> int:
        return int(self.get(name).nbytes)


class FileBackend(StorageBackend):
    tier = "file"

    def __init__(self, root: str | Path,
                 profile: TierProfile = PROFILES["native"]):
        super().__init__(profile)
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, name: str) -> Path:
        return self.root / f"{name}.npy"

    def put(self, name: str, value: np.ndarray) -> None:
        value = np.asarray(value)
        self.profile.charge(value.nbytes, write=True)
        path = self._path(name)
        path.parent.mkdir(parents=True, exist_ok=True)
        # write-to-temp + atomic rename: a concurrent reader of an
        # overwritten key sees the old bytes or the new bytes, never a
        # truncated file (the either-tier-consistency the staging
        # protocol promises ends at this backend's put).  The bytes are
        # laid down by the codec registry (raw-header fast path for
        # numeric arrays, pickle tail for object dtypes) so the format is
        # pluggable without forking this transport.
        tmp = path.with_suffix(f".{os.getpid()}.{threading.get_ident()}.tmp")
        try:
            codec = _codecs.encoder_for(value)
            with open(tmp, "wb") as f:
                codec.write(f, value)
            os.replace(tmp, path)
        finally:
            tmp.unlink(missing_ok=True)

    def get(self, name: str) -> np.ndarray:
        """Read-only partition bytes.  Zero-copy by default: the raw
        codec maps the file (``mmap_mode="r"``) instead of memcpy'ing the
        payload, so fetch cost is a page-table update and the simulated
        profile charge — a reader's live view pins the inode even across
        a concurrent overwrite (``os.replace``) or delete."""
        arr = _codecs.decode_file(self._path(name))
        self.profile.charge(arr.nbytes, write=False)
        return arr

    def nbytes(self, name: str) -> int:
        # header-only read (codec registry): sizing a partition (e.g. for
        # interconnect cost modelling) must not charge the simulated
        # bandwidth profile nor touch the payload pages
        return _codecs.file_nbytes(self._path(name))

    def delete(self, name: str) -> None:
        self._path(name).unlink(missing_ok=True)

    def exists(self, name: str) -> bool:
        return self._path(name).exists()


class ObjectStoreBackend(FileBackend):
    """File storage behind an object-store-like latency profile."""
    tier = "object"

    def __init__(self, root: str | Path,
                 profile: TierProfile = PROFILES["object_store"]):
        super().__init__(root, profile)


class CheckpointBackend(StorageBackend):
    """Durable coldest tier: atomic .npy files + an fsync'd JSON manifest.

    Write-behind: `put` buffers the value and enqueues it for a single
    writer thread (the CheckpointManager async-save pattern), which lands
    each partition atomically (write to a .tmp sibling, `os.replace`) and
    batches manifest rewrites.  Reads of a still-pending key are served
    from the buffer, so the copy-first/delete-last move protocol stays
    hole-free while bytes drain to disk.  `flush()` waits for every queued
    write to land and fsyncs the manifest; `close()` flushes and joins the
    writer.  A fresh CheckpointBackend over an existing root loads the
    manifest, so a reopened store is self-describing (keys, sizes) without
    touching the data files.

    One instance may safely back several TierManagers (the multi-pilot
    shared home): all metadata is lock-guarded and file writes are atomic,
    so two pilots demoting the same replica key write identical bytes.
    """
    tier = "checkpoint"

    _MANIFEST = "MANIFEST.json"

    def __init__(self, root: str | Path,
                 profile: TierProfile = PROFILES["native"],
                 max_pending_bytes: int = 128 * 2 ** 20):
        super().__init__(profile)
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_pending_bytes = int(max_pending_bytes)
        self._lock = threading.RLock()
        self._space = threading.Condition(self._lock)
        self._manifest: Dict[str, dict] = {}     # key -> {file, nbytes, ...}
        self._pending: Dict[str, np.ndarray] = {}  # buffered, not yet on disk
        self._pending_bytes = 0
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._writer: Optional[threading.Thread] = None
        self._closed = False
        self._manifest_dirty = False
        self.counters: Dict[str, int] = {
            "writes": 0, "reads": 0, "manifest_flushes": 0}
        mpath = self.root / self._MANIFEST
        if mpath.exists():
            try:
                self._manifest = json.loads(mpath.read_text()).get("keys", {})
            except (OSError, ValueError):
                self._manifest = {}

    # -- paths / manifest ----------------------------------------------
    def _path(self, name: str) -> Path:
        return self.root / f"{name}.npy"

    def _write_manifest_locked(self, fsync: bool = False) -> None:
        doc = {"schema": "repro-checkpoint-tier.v1", "keys": self._manifest}
        tmp = self.root / (self._MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            f.write(json.dumps(doc, sort_keys=True))
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, self.root / self._MANIFEST)
        if fsync:
            dirfd = os.open(self.root, os.O_RDONLY)
            try:
                os.fsync(dirfd)
            finally:
                os.close(dirfd)
        self._manifest_dirty = False
        self.counters["manifest_flushes"] += 1

    # -- async writer ---------------------------------------------------
    def _ensure_writer_locked(self) -> None:
        if self._writer is None or not self._writer.is_alive():
            self._writer = threading.Thread(
                target=self._writer_loop, daemon=True,
                name="checkpoint-writer")
            self._writer.start()

    def _writer_loop(self) -> None:
        while True:
            key = self._queue.get()
            if key is None:
                self._queue.task_done()
                return
            try:
                self._land(key)
            finally:
                self._queue.task_done()

    def _land(self, key: str) -> None:
        """Write one pending key to disk atomically; skip if it was deleted
        (or re-put) while queued."""
        with self._lock:
            arr = self._pending.get(key)
        if arr is None:
            return
        self.profile.charge(int(arr.nbytes), write=True)
        path = self._path(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.parent / (path.name + ".tmp")
        codec = _codecs.encoder_for(arr)
        with open(tmp, "wb") as f:     # file object: the codec must not
            codec.write(f, arr)        # append .npy to the tmp name
        with self._lock:
            if self._pending.get(key) is not arr:
                tmp.unlink(missing_ok=True)   # deleted/replaced mid-write
                return
            os.replace(tmp, path)
            del self._pending[key]
            self._pending_bytes -= int(arr.nbytes)
            self._space.notify_all()
            self._manifest[key] = {
                "file": path.name, "nbytes": int(arr.nbytes),
                "shape": list(arr.shape), "dtype": str(arr.dtype)}
            self._manifest_dirty = True
            self.counters["writes"] += 1
            # batch manifest rewrites: only when the queue has drained
            if self._queue.unfinished_tasks <= 1:
                self._write_manifest_locked()

    # -- StorageBackend surface ----------------------------------------
    def put(self, name: str, value: np.ndarray) -> None:
        arr = np.asarray(value)
        with self._space:
            if self._closed:
                # post-close stores write synchronously (durability over
                # latency once the writer is gone)
                self._pending[name] = arr
                self._land(name)
                self._write_manifest_locked(fsync=True)
                return
            # backpressure: the write-behind buffer is byte-bounded, so a
            # spill under memory pressure actually frees RAM instead of
            # parking the whole overflow in _pending while the (possibly
            # throttled) writer drains; an oversized single value is
            # admitted once the buffer is empty
            while (self._pending_bytes
                   and self._pending_bytes + int(arr.nbytes)
                   > self.max_pending_bytes):
                self._space.wait(1.0)
            old = self._pending.get(name)
            if old is not None:
                self._pending_bytes -= int(old.nbytes)
            self._pending[name] = arr
            self._pending_bytes += int(arr.nbytes)
            self._ensure_writer_locked()
            self._queue.put(name)

    def get(self, name: str) -> np.ndarray:
        with self._lock:
            arr = self._pending.get(name)
            if arr is None and name not in self._manifest:
                raise KeyError(name)
        if arr is not None:
            # buffered write: a read-only aliasing view of the pending
            # buffer — a reader must never scribble into bytes the writer
            # thread is about to land
            return as_view(arr)
        # landed bytes: zero-copy restore (mmap'd raw fast path) — the
        # checkpoint-restore hop no longer memcpy's the whole partition
        arr = _codecs.decode_file(self._path(name))
        self.profile.charge(int(arr.nbytes), write=False)
        with self._lock:
            self.counters["reads"] += 1
        return arr

    def delete(self, name: str) -> None:
        with self._lock:
            dropped = self._pending.pop(name, None)
            if dropped is not None:
                self._pending_bytes -= int(dropped.nbytes)
                self._space.notify_all()
            had = self._manifest.pop(name, None)
            self._path(name).unlink(missing_ok=True)
            if had is not None:
                self._manifest_dirty = True

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._pending or name in self._manifest

    def nbytes(self, name: str) -> int:
        with self._lock:
            arr = self._pending.get(name)
            if arr is not None:
                return int(arr.nbytes)
            info = self._manifest.get(name)
            if info is not None:
                return int(info["nbytes"])
        raise KeyError(name)

    def keys(self) -> List[str]:
        """Every key the store holds (pending or landed) — the reopen
        surface: a fresh manager can adopt these."""
        with self._lock:
            return sorted(set(self._pending) | set(self._manifest))

    # -- durability -----------------------------------------------------
    def flush(self, timeout: Optional[float] = None) -> None:
        """Deterministic write barrier: every buffered put is on disk and
        the manifest is fsync'd when this returns.  On a store shared
        across managers this waits for EVERY holder's queued writes (it
        is one directory and one manifest); `timeout` bounds the wait and
        raises TimeoutError with writes still in flight."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self._queue.all_tasks_done:
            while self._queue.unfinished_tasks:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        "checkpoint flush timed out with writes in flight")
                self._queue.all_tasks_done.wait(remaining)
        with self._lock:
            self._write_manifest_locked(fsync=True)

    def close(self) -> None:
        """Flush, then stop and join the writer thread (idempotent; reads
        and synchronous writes keep working afterwards)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            writer = self._writer
        self._queue.join()
        if writer is not None and writer.is_alive():
            self._queue.put(None)
            writer.join(timeout=30)
        with self._lock:
            self._write_manifest_locked(fsync=True)


# shared checkpoint stores: pilots naming the same checkpoint_dir must hit
# the SAME instance (one manifest writer per directory), which is also what
# makes the store a shared home the PilotDataService can recover from
_CHECKPOINT_STORES: Dict[str, CheckpointBackend] = {}
_CHECKPOINT_STORES_LOCK = threading.Lock()


def checkpoint_store(root: str | Path,
                     profile: TierProfile = PROFILES["native"]
                     ) -> CheckpointBackend:
    """The CheckpointBackend for `root`, shared per resolved directory.
    A closed cached instance is replaced by a fresh one that reloads the
    manifest (the reopen path)."""
    key = str(Path(root).resolve())
    with _CHECKPOINT_STORES_LOCK:
        be = _CHECKPOINT_STORES.get(key)
        if be is None or be._closed:
            be = CheckpointBackend(root, profile)
            _CHECKPOINT_STORES[key] = be
        return be


class HostMemoryBackend(StorageBackend):
    """Process-resident numpy store (the paper's Redis analogue)."""
    tier = "host"

    def __init__(self, profile: TierProfile = PROFILES["native"]):
        super().__init__(profile)
        self._store: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()

    def put(self, name: str, value: np.ndarray) -> None:
        value = np.asarray(value)
        self.profile.charge(value.nbytes, write=True)
        with self._lock:
            self._store[name] = value

    def get(self, name: str) -> np.ndarray:
        with self._lock:
            arr = self._store[name]
        self.profile.charge(arr.nbytes, write=False)
        # read-only aliasing view (copy mode: an owned copy — the
        # pre-PR-8 baseline the transport bench measures against).  A
        # demotion/overwrite/delete only drops the STORE's reference;
        # a reader's live view keeps the old bytes alive and unchanged.
        if zero_copy_enabled():
            return as_view(arr)
        return as_view(materialize(arr), count=False)

    def delete(self, name: str) -> None:
        with self._lock:
            self._store.pop(name, None)

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._store


class DeviceBackend(StorageBackend):
    """HBM-resident jax.Arrays, optionally sharded over a pilot's mesh.

    This is the Pilot-Data *Memory* tier: data put here is retained on the
    accelerators across Compute-Units (the paper's Spark-backend role) so
    iterative analytics never re-stage inputs (the 212x KMeans effect).
    """
    tier = "device"

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None,
                 pspec: Optional[jax.sharding.PartitionSpec] = None,
                 profile: TierProfile = PROFILES["native"]):
        super().__init__(profile)
        self.mesh = mesh
        self.pspec = pspec
        self._store: Dict[str, jax.Array] = {}
        self._lock = threading.Lock()

    def _sharding(self, value: np.ndarray):
        if self.mesh is None:
            return None
        spec = self.pspec
        if spec is None:
            axis = self.mesh.axis_names[0]
            size = self.mesh.devices.shape[0]
            spec = (jax.sharding.PartitionSpec(axis)
                    if value.ndim and value.shape[0] % size == 0
                    else jax.sharding.PartitionSpec())
        return jax.sharding.NamedSharding(self.mesh, spec)

    def put(self, name: str, value) -> None:
        if isinstance(value, jax.Array):
            self.profile.charge(int(value.nbytes), write=True)
            arr = value
        else:
            host = np.asarray(value)
            self.profile.charge(int(host.nbytes), write=True)
            arr = jax.device_put(host, self._sharding(host))
        with self._lock:
            self._store[name] = arr

    def get_device(self, name: str) -> jax.Array:
        with self._lock:
            return self._store[name]

    def get(self, name: str) -> np.ndarray:
        arr = self.get_device(name)
        self.profile.charge(arr.nbytes, write=False)
        if zero_copy_enabled():
            # dlpack: a read-only host view straight over the device
            # buffer when it is host-addressable (CPU jax, unified
            # memory); None means real HBM — that tier crossing is a
            # genuine copy and falls through
            v = device_view(arr)
            if v is not None:
                return v
        return as_view(materialize(arr), count=False)

    def delete(self, name: str) -> None:
        with self._lock:
            self._store.pop(name, None)

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._store


def make_backend(tier: str, *, root: Optional[str] = None,
                 profile: TierProfile = PROFILES["native"],
                 mesh=None, pspec=None) -> StorageBackend:
    if tier == "checkpoint":
        return checkpoint_store(root or ".pilot_checkpoint", profile)
    if tier == "file":
        return FileBackend(root or ".pilot_data", profile)
    if tier == "object":
        return ObjectStoreBackend(root or ".pilot_object", profile)
    if tier == "host":
        return HostMemoryBackend(profile)
    if tier == "device":
        return DeviceBackend(mesh=mesh, pspec=pspec, profile=profile)
    raise ValueError(f"unknown tier {tier!r}")
