"""Pilot-Data storage tiers: one DataUnit API over heterogeneous backends.

Paper mapping (§3.1/§3.3): the paper's pluggable Pilot-Data backends
(local disk / Lustre / HDFS / Redis / Spark-RDD) become storage *tiers* of a
TPU system:

  file    — mmap'd .npy on disk            (paper: file backend, Lustre/HDFS)
  object  — file + simulated WAN latency   (paper: cloud object store, S3)
  host    — process-resident numpy         (paper: Redis in-memory store)
  device  — jax.Arrays resident in HBM     (paper: Spark executor memory)

Backends expose a bandwidth/latency profile so benchmarks can reproduce the
paper's Stampede-disk vs Gordon-flash comparison (Fig. 7/8) on one box: the
simulated profiles throttle honestly (sleep for bytes/bw) and are clearly
labeled as simulations in benchmark output.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import jax
import numpy as np

TIERS = ("file", "object", "host", "device")


@dataclasses.dataclass(frozen=True)
class TierProfile:
    """Bandwidth/latency model for a simulated storage tier."""
    name: str
    read_bw: float = 0.0       # bytes/s; 0 = unthrottled (native speed)
    write_bw: float = 0.0
    latency: float = 0.0       # seconds per operation
    simulate: bool = False

    def charge(self, nbytes: int, write: bool) -> None:
        if not self.simulate:
            return
        bw = self.write_bw if write else self.read_bw
        t = self.latency + (nbytes / bw if bw else 0.0)
        if t > 0:
            time.sleep(min(t, 5.0))  # cap: benchmarks stay bounded


# Published-order-of-magnitude profiles for the Fig. 7/8 reproductions.
PROFILES: Dict[str, TierProfile] = {
    "stampede_disk": TierProfile("stampede_disk", 120e6, 90e6, 5e-3, True),
    "gordon_flash": TierProfile("gordon_flash", 800e6, 500e6, 1e-4, True),
    "lustre": TierProfile("lustre", 300e6, 200e6, 2e-3, True),
    "hdfs": TierProfile("hdfs", 250e6, 80e6, 8e-3, True),
    "object_store": TierProfile("object_store", 80e6, 40e6, 50e-3, True),
    "native": TierProfile("native"),
}

# Nominal read/write bandwidth (bytes/s) per tier when its profile runs
# unthrottled; cost-aware eviction (GDSF) uses these so restage costs stay
# ordered (file < object << host << device) even without simulated profiles.
DEFAULT_TIER_BANDWIDTH: Dict[str, float] = {
    "file": 200e6, "object": 80e6, "host": 10e9, "device": 60e9,
}


class StorageBackend:
    """One tier's put/get/delete over named partitions."""

    tier: str = "file"

    def __init__(self, profile: TierProfile = PROFILES["native"]):
        self.profile = profile

    def put(self, name: str, value: np.ndarray) -> None:
        raise NotImplementedError

    def get(self, name: str) -> np.ndarray:
        raise NotImplementedError

    def delete(self, name: str) -> None:
        raise NotImplementedError

    def exists(self, name: str) -> bool:
        raise NotImplementedError

    def nbytes(self, name: str) -> int:
        return int(self.get(name).nbytes)


class FileBackend(StorageBackend):
    tier = "file"

    def __init__(self, root: str | Path,
                 profile: TierProfile = PROFILES["native"]):
        super().__init__(profile)
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, name: str) -> Path:
        return self.root / f"{name}.npy"

    def put(self, name: str, value: np.ndarray) -> None:
        value = np.asarray(value)
        self.profile.charge(value.nbytes, write=True)
        path = self._path(name)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.save(path, value)

    def get(self, name: str) -> np.ndarray:
        arr = np.load(self._path(name), mmap_mode=None)
        self.profile.charge(arr.nbytes, write=False)
        return arr

    def delete(self, name: str) -> None:
        self._path(name).unlink(missing_ok=True)

    def exists(self, name: str) -> bool:
        return self._path(name).exists()


class ObjectStoreBackend(FileBackend):
    """File storage behind an object-store-like latency profile."""
    tier = "object"

    def __init__(self, root: str | Path,
                 profile: TierProfile = PROFILES["object_store"]):
        super().__init__(root, profile)


class HostMemoryBackend(StorageBackend):
    """Process-resident numpy store (the paper's Redis analogue)."""
    tier = "host"

    def __init__(self, profile: TierProfile = PROFILES["native"]):
        super().__init__(profile)
        self._store: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()

    def put(self, name: str, value: np.ndarray) -> None:
        value = np.asarray(value)
        self.profile.charge(value.nbytes, write=True)
        with self._lock:
            self._store[name] = value

    def get(self, name: str) -> np.ndarray:
        with self._lock:
            arr = self._store[name]
        self.profile.charge(arr.nbytes, write=False)
        return arr

    def delete(self, name: str) -> None:
        with self._lock:
            self._store.pop(name, None)

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._store


class DeviceBackend(StorageBackend):
    """HBM-resident jax.Arrays, optionally sharded over a pilot's mesh.

    This is the Pilot-Data *Memory* tier: data put here is retained on the
    accelerators across Compute-Units (the paper's Spark-backend role) so
    iterative analytics never re-stage inputs (the 212x KMeans effect).
    """
    tier = "device"

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None,
                 pspec: Optional[jax.sharding.PartitionSpec] = None,
                 profile: TierProfile = PROFILES["native"]):
        super().__init__(profile)
        self.mesh = mesh
        self.pspec = pspec
        self._store: Dict[str, jax.Array] = {}
        self._lock = threading.Lock()

    def _sharding(self, value: np.ndarray):
        if self.mesh is None:
            return None
        spec = self.pspec
        if spec is None:
            axis = self.mesh.axis_names[0]
            size = self.mesh.devices.shape[0]
            spec = (jax.sharding.PartitionSpec(axis)
                    if value.ndim and value.shape[0] % size == 0
                    else jax.sharding.PartitionSpec())
        return jax.sharding.NamedSharding(self.mesh, spec)

    def put(self, name: str, value) -> None:
        if isinstance(value, jax.Array):
            self.profile.charge(int(value.nbytes), write=True)
            arr = value
        else:
            host = np.asarray(value)
            self.profile.charge(int(host.nbytes), write=True)
            arr = jax.device_put(host, self._sharding(host))
        with self._lock:
            self._store[name] = arr

    def get_device(self, name: str) -> jax.Array:
        with self._lock:
            return self._store[name]

    def get(self, name: str) -> np.ndarray:
        arr = self.get_device(name)
        self.profile.charge(arr.nbytes, write=False)
        return np.asarray(arr)

    def delete(self, name: str) -> None:
        with self._lock:
            self._store.pop(name, None)

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._store


def make_backend(tier: str, *, root: Optional[str] = None,
                 profile: TierProfile = PROFILES["native"],
                 mesh=None, pspec=None) -> StorageBackend:
    if tier == "file":
        return FileBackend(root or ".pilot_data", profile)
    if tier == "object":
        return ObjectStoreBackend(root or ".pilot_object", profile)
    if tier == "host":
        return HostMemoryBackend(profile)
    if tier == "device":
        return DeviceBackend(mesh=mesh, pspec=pspec, profile=profile)
    raise ValueError(f"unknown tier {tier!r}")
