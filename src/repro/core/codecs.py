"""Pluggable codec registry for the file-backed tiers.

Before PR 8 every file-backed tier baked in one implicit convention —
``np.save`` on write, a full ``np.load`` (header parse + memcpy of the
whole payload) on read — so fetch bandwidth was set by memcpy no matter
how fast the tier was.  This module makes the encode path a *registry*
(the RADICAL-Pilot ``serializer.py`` idiom: codecs register themselves,
the first one whose predicate accepts the value wins, and callers never
fork the transport to add a format):

  * ``RawCodec`` — the fast path for plain numeric ndarrays: the ``.npy``
    container (a self-describing header followed by the raw buffer), read
    back with ``mmap_mode="r"`` so decode is a page-table update, not a
    memcpy — the zero-copy read the ``Buf`` plane moves around.  Sizing
    (``file_nbytes``) is a header-only read;
  * ``PickleCodec`` — the compatibility tail for object-dtype arrays,
    which cannot be mmap'd; encodes via ``np.save(allow_pickle=True)``
    and decodes with a full (copying) load;
  * ``register_codec`` — prepend a custom codec (e.g. a compressing one)
    without touching any backend: both ``FileBackend`` and
    ``CheckpointBackend`` encode through ``encoder_for`` and decode
    through ``decode_file``, which sniffs the container and falls back
    down the chain.

Every encode/decode records a per-codec counter in
``repro.core.buf.STATS`` (surfaced as ``session.stats()["transport"]
["codec"]``), so benchmarks can attribute bytes to the path that moved
them.
"""
from __future__ import annotations

from pathlib import Path
from typing import BinaryIO, List, Union

import numpy as np

from repro.core.buf import STATS, as_view, zero_copy_enabled


class Codec:
    """One encode/decode format for partition files.

    ``accepts`` gates encoding (first matching codec in the registry
    wins); ``write`` lands the value into an open binary file object (the
    backends own atomicity: tmp + ``os.replace``); ``read`` returns the
    decoded array — a read-only view when ``prefer_view`` and the format
    allows it, else an owned copy; ``nbytes`` sizes a file without
    touching its payload.
    """

    name = "codec"

    def accepts(self, arr: np.ndarray) -> bool:
        raise NotImplementedError

    def write(self, f: BinaryIO, arr: np.ndarray) -> None:
        raise NotImplementedError

    def read(self, path: Path, prefer_view: bool = True) -> np.ndarray:
        raise NotImplementedError

    def nbytes(self, path: Path) -> int:
        raise NotImplementedError


class RawCodec(Codec):
    """Raw-header fast path: .npy container, mmap'd zero-copy decode."""

    name = "raw"

    def accepts(self, arr: np.ndarray) -> bool:
        return arr.dtype != object

    def write(self, f: BinaryIO, arr: np.ndarray) -> None:
        # np.save writes the raw buffer after a self-describing header;
        # a non-contiguous array is made contiguous by numpy internally
        # (that copy is the format's, not the transport's)
        np.save(f, arr)
        STATS.record_codec(self.name, "encode")

    def read(self, path: Path, prefer_view: bool = True) -> np.ndarray:
        if prefer_view and zero_copy_enabled():
            arr = np.load(path, mmap_mode="r")      # page map, no memcpy
            STATS.record_view(arr.nbytes)
        else:
            arr = np.load(path, mmap_mode=None)
            STATS.record_copy(arr.nbytes)
            arr = as_view(arr, count=False)     # the contract: reads are RO
        STATS.record_codec(self.name, "decode")
        return arr

    def nbytes(self, path: Path) -> int:
        # header-only: open the mmap (no payload pages touched) and size it
        return int(np.load(path, mmap_mode="r").nbytes)


class PickleCodec(Codec):
    """Object-dtype tail: pickled .npy, always a materializing decode."""

    name = "pickle"

    def accepts(self, arr: np.ndarray) -> bool:
        return True

    def write(self, f: BinaryIO, arr: np.ndarray) -> None:
        np.save(f, arr, allow_pickle=True)
        STATS.record_codec(self.name, "encode")

    def read(self, path: Path, prefer_view: bool = True) -> np.ndarray:
        arr = np.load(path, mmap_mode=None, allow_pickle=True)
        STATS.record_copy(arr.nbytes)
        STATS.record_codec(self.name, "decode")
        return as_view(arr, count=False)

    def nbytes(self, path: Path) -> int:
        return int(np.load(path, mmap_mode=None, allow_pickle=True).nbytes)


_REGISTRY: List[Codec] = [RawCodec(), PickleCodec()]


def register_codec(codec: Codec, front: bool = True) -> Codec:
    """Plug a codec into the chain (front=True: it is consulted first)."""
    if front:
        _REGISTRY.insert(0, codec)
    else:
        _REGISTRY.append(codec)
    return codec


def unregister_codec(codec: Codec) -> None:
    if codec in _REGISTRY:
        _REGISTRY.remove(codec)


def codecs() -> List[Codec]:
    return list(_REGISTRY)


def encoder_for(arr: np.ndarray) -> Codec:
    """The first registered codec accepting `arr` (PickleCodec accepts
    everything, so the chain never misses)."""
    for c in _REGISTRY:
        if c.accepts(arr):
            return c
    return _REGISTRY[-1]


def decode_file(path: Union[str, Path],
                prefer_view: bool = True) -> np.ndarray:
    """Decode a partition file down the registry chain: the raw mmap fast
    path first, falling back (e.g. pickled object arrays refuse to mmap)
    until a codec succeeds."""
    path = Path(path)
    last: Exception = KeyError(str(path))
    for c in _REGISTRY:
        try:
            return c.read(path, prefer_view=prefer_view)
        except FileNotFoundError:
            raise
        except (ValueError, OSError) as e:   # wrong format for this codec
            last = e
    raise last


def file_nbytes(path: Union[str, Path]) -> int:
    """Size a partition file without reading its payload (header-only on
    the raw fast path)."""
    path = Path(path)
    last: Exception = KeyError(str(path))
    for c in _REGISTRY:
        try:
            return c.nbytes(path)
        except FileNotFoundError:
            raise
        except (ValueError, OSError) as e:
            last = e
    raise last
