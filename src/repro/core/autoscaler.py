"""Elastic autoscaling: grow/shrink the pilot fleet from live load.

The paper's central claim is that the Pilot-Abstraction *dynamically*
allocates and manages resources across heterogeneous infrastructures —
but through PR 9 the fleet was static after ``add_pilots``: the
supervisor only replaced dead pilots, never resized the pool.  The
Hadoop-on-HPC follow-up (arXiv:1602.00345) makes pilot-managed *elastic*
resource pools the piece that pays off for bursty data-intensive work;
this module is that control loop:

  * ``ScalingSignals`` — one fused snapshot of everything the fleet
    knows about its own load: the task engine's dispatch backlog and
    accepted-CU counts (through the same backend ``health()`` probe the
    supervisor trusts), per-pilot worker utilization, tier pressure from
    each pilot's ``TierManager`` budgets, and serving queue wait from
    every ``ServingEngine`` registered with the session.

  * ``ScalingPolicy`` / ``LoadScalingPolicy`` — the pluggable decision:
    the default is watermark-based with *hysteresis* (a breach must
    persist for ``hysteresis`` consecutive ticks before acting, so one
    bursty sample never provisions a node) and the Autoscaler adds a
    *cooldown* after every action (a freshly added pilot must get a
    chance to absorb load before the next decision).

  * ``Autoscaler`` — the monitor thread.  Scale-OUT clones a template
    ``PilotComputeDescription`` (default: the current fleet's own)
    through ``session.add_pilot`` — exactly the provision path the
    supervisor's respawn uses, so new pilots join the data service,
    scheduling, and (via the serving reaper's adoption sweep) the
    serving fleet with no extra wiring.  Scale-IN runs the drain
    protocol:

      1. ``SchedulingPolicy.drain(victim)`` — no new CU, engine task, or
         serving request routes to the victim (it stays healthy and
         keeps serving replica reads);
      2. every ``ServingEngine`` hands off the victim's replica —
         in-flight requests are recovered from durable KV pages and
         re-routed exactly like a reaped dead replica;
      3. the victim quiesces: accepted CUs retire, the worker pool's
         backlog drains (bounded by ``drain_timeout_s``);
      4. ``PilotDataService.evacuate_pilot`` migrates or
         checkpoint-flushes every resident partition (priced by the
         InterconnectModel; a partition that cannot be saved ABORTS the
         scale-in);
      5. ``session.release(victim)`` — the supervisor forgets it first,
         so a deliberate release is never mistaken for a death.

    A victim that dies mid-drain (chaos racing the scaler) aborts the
    drain and is left to the supervisor; the next scale-in picks a
    different victim (quarantined and respawn-handled pilots are never
    victims).

Every decision — including rejections — is recorded with the signal
snapshot that drove it and surfaces through ``stats()`` /
``session.stats()["autoscaler"]``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.pilot import PilotCompute, PilotComputeDescription, State

# bounded decision history: enough to audit a long soak, never unbounded
_MAX_DECISIONS = 512


@dataclasses.dataclass
class ScalingSignals:
    """One snapshot of the live-load signals a ScalingPolicy reads."""
    t: float = 0.0                  # wall-clock stamp (telemetry only)
    n_pilots: int = 0               # RUNNING pilots
    queue_depth: int = 0            # task-engine dispatch backlog (sum)
    pending_cus: int = 0            # accepted-but-unfinished classic CUs
    workers: int = 0                # total resident task workers
    load: float = 0.0               # (queue_depth + pending) / workers
    tier_pressure: float = 0.0      # max volatile usage/budget, any pilot
    serving_queued: int = 0         # routed-but-waiting serving requests
    serving_wait_s: float = 0.0     # oldest serving request's queue wait
    per_pilot: Dict[str, float] = dataclasses.field(default_factory=dict)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ScalingDecision:
    """One autoscaler decision (actions AND rejections), with the signal
    values that drove it — the acceptance contract of stats()."""
    t: float
    action: str         # "scale-out"|"scale-in"|"scale-in-aborted"|"reject"
    reason: str
    pilot: str          # newcomer (out) / victim (in) pilot id, "" if none
    signals: dict
    detail: dict = dataclasses.field(default_factory=dict)


class ScalingPolicy:
    """Strategy interface: map one ScalingSignals snapshot to an action.

    ``decide`` returns ``("out" | "in" | "hold", reason)``.  Policies own
    their hysteresis state (consecutive-breach counters); the Autoscaler
    owns cooldown and the min/max clamps."""

    name = "scaling-policy"

    def decide(self, signals: ScalingSignals) -> Tuple[str, str]:
        raise NotImplementedError


class LoadScalingPolicy(ScalingPolicy):
    """Watermark policy with hysteresis.

    Scale OUT when any hot signal breaches for ``hysteresis`` consecutive
    ticks: backlog per worker >= ``scale_out_load``, serving queue wait
    >= ``serving_wait_s``, or volatile tier pressure >= ``tier_pressure``
    (migrate-ahead-of-the-hot-spot: a fleet running out of fast memory
    needs capacity before it starts thrashing the durable tier).

    Scale IN only when EVERY signal is cold for ``in_hysteresis``
    consecutive ticks (default 2x the out hysteresis — releasing a node
    is the expensive mistake): backlog per worker <= ``scale_in_load``,
    no serving queue, and tier pressure below the watermark."""

    name = "load-watermark"

    def __init__(self, scale_out_load: float = 1.5,
                 scale_in_load: float = 0.25,
                 serving_wait_s: float = 0.5,
                 tier_pressure: float = 0.92,
                 hysteresis: int = 2,
                 in_hysteresis: Optional[int] = None):
        if scale_in_load >= scale_out_load:
            raise ValueError(
                f"scale_in_load ({scale_in_load}) must be below "
                f"scale_out_load ({scale_out_load}) — equal watermarks "
                "oscillate")
        self.scale_out_load = float(scale_out_load)
        self.scale_in_load = float(scale_in_load)
        self.serving_wait_s = float(serving_wait_s)
        self.tier_pressure = float(tier_pressure)
        self.hysteresis = max(1, int(hysteresis))
        self.in_hysteresis = (2 * self.hysteresis if in_hysteresis is None
                              else max(1, int(in_hysteresis)))
        self._hot = 0
        self._cold = 0

    def decide(self, s: ScalingSignals) -> Tuple[str, str]:
        hot: List[str] = []
        if s.workers and s.load >= self.scale_out_load:
            hot.append(f"load {s.load:.2f} >= {self.scale_out_load}")
        if s.serving_wait_s >= self.serving_wait_s and s.serving_queued:
            hot.append(f"serving wait {s.serving_wait_s:.2f}s >= "
                       f"{self.serving_wait_s}s")
        if s.tier_pressure >= self.tier_pressure:
            hot.append(f"tier pressure {s.tier_pressure:.2f} >= "
                       f"{self.tier_pressure}")
        if hot:
            self._hot += 1
            self._cold = 0
            if self._hot >= self.hysteresis:
                return "out", "; ".join(hot)
            return "hold", f"hot {self._hot}/{self.hysteresis}: " \
                           + "; ".join(hot)
        self._hot = 0
        cold = (s.load <= self.scale_in_load
                and s.serving_queued == 0
                and s.tier_pressure < self.tier_pressure)
        if cold:
            self._cold += 1
            if self._cold >= self.in_hysteresis:
                return "in", (f"load {s.load:.2f} <= {self.scale_in_load}, "
                              "serving idle")
            return "hold", f"cold {self._cold}/{self.in_hysteresis}"
        self._cold = 0
        return "hold", "in band"


class Autoscaler:
    """The elastic control loop over a PilotSession (see module doc).

    Knobs
    -----
    min_pilots / max_pilots: fleet-size clamps (scale-in never drops the
        fleet below min; scale-out never exceeds max, nor the backend's
        reported ``capacity()``).
    policy: a ScalingPolicy (default LoadScalingPolicy()).
    template: the PilotComputeDescription scale-out clones (default: the
        first running pilot's own description — growth looks exactly
        like the fleet that exists).
    interval_s: monitor tick period.
    cooldown_s: minimum quiet time after any scaling action before the
        policy may act again (manual scale_out/scale_in bypass it).
    drain_timeout_s: bound on the scale-in quiesce phase.

    ``start()`` launches the monitor thread; a bare (unstarted)
    Autoscaler is a valid manual scaler — ``scale_out``/``scale_in`` are
    the public verbs the elastic runtime delegates to.
    """

    def __init__(self, session, *, min_pilots: int = 1, max_pilots: int = 8,
                 policy: Optional[ScalingPolicy] = None,
                 template: Optional[PilotComputeDescription] = None,
                 interval_s: float = 0.05, cooldown_s: float = 0.25,
                 drain_timeout_s: float = 15.0):
        if min_pilots < 1:
            raise ValueError(f"min_pilots must be >= 1, got {min_pilots}")
        if max_pilots < min_pilots:
            raise ValueError(f"max_pilots ({max_pilots}) must be >= "
                             f"min_pilots ({min_pilots})")
        self.session = session
        self.min_pilots = int(min_pilots)
        self.max_pilots = int(max_pilots)
        self.policy = policy or LoadScalingPolicy()
        self.template = template
        self.interval_s = max(0.005, float(interval_s))
        self.cooldown_s = max(0.0, float(cooldown_s))
        self.drain_timeout_s = float(drain_timeout_s)
        self.decisions: List[ScalingDecision] = []
        self.counters: Dict[str, int] = {
            "scale_outs": 0, "scale_ins": 0, "aborted_drains": 0,
            "rejects": 0, "ticks": 0}
        self._last_signals: Optional[ScalingSignals] = None
        self._last_action_t = 0.0
        self._lock = threading.Lock()       # decisions/counters
        self._scale_lock = threading.Lock()  # serializes fleet changes
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._started = False

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "Autoscaler":
        if self._started:
            return self
        self._started = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="pilot-autoscaler")
        self._thread.start()
        return self

    def close(self, timeout: float = 10.0) -> None:
        """Stop the monitor (joins the thread, so an in-flight drain
        finishes or aborts before this returns).  Idempotent."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)

    # -- signal collection -----------------------------------------------
    def _running_pilots(self) -> List[PilotCompute]:
        return [p for p in self.session.pilots
                if p.state is State.RUNNING]

    def collect_signals(self) -> ScalingSignals:
        """One fused load snapshot, read through the SAME backend
        ``health()`` probe the supervisor trusts (so a stalled adaptor
        looks as dead to the scaler as to the failure detector)."""
        from repro.core.backends.base import get_backend
        s = ScalingSignals(t=time.time())
        for p in self._running_pilots():
            try:
                h = get_backend(p.desc.backend).health(p)
            except Exception:   # noqa: BLE001 - dying adaptor: skip pilot
                continue
            s.n_pilots += 1
            depth = int(h.get("pool_depth", 0))
            pend = int(h.get("queued", 0)) + int(h.get("busy", False))
            workers = max(1, int(h.get("task_workers", 1)))
            s.queue_depth += depth
            s.pending_cus += pend
            s.workers += workers
            s.per_pilot[p.id] = float(h.get("utilization",
                                            depth + pend)) / workers
            tm = getattr(p, "tier_manager", None)
            if tm is not None:
                try:
                    for tier, st in tm.stats().items():
                        budget = st.get("budget")
                        if tier in ("device", "host") and budget:
                            s.tier_pressure = max(
                                s.tier_pressure, st["usage"] / budget)
                except Exception:   # noqa: BLE001 - closing manager
                    pass
        if s.workers:
            s.load = (s.queue_depth + s.pending_cus) / s.workers
        for eng in list(getattr(self.session, "serving_engines", ())):
            try:
                sl = eng.load()
            except Exception:   # noqa: BLE001 - engine mid-close
                continue
            s.serving_queued += int(sl.get("queued", 0))
            s.serving_wait_s = max(s.serving_wait_s,
                                   float(sl.get("oldest_wait_s", 0.0)))
        with self._lock:
            self._last_signals = s
        return s

    # -- the control loop ------------------------------------------------
    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self._tick()
            except Exception:   # noqa: BLE001 - loop survives races
                pass

    def _cooling(self) -> bool:
        return (time.monotonic() - self._last_action_t) < self.cooldown_s

    def _tick(self) -> None:
        with self._lock:
            self.counters["ticks"] += 1
        if getattr(self.session, "closed", False):
            return
        signals = self.collect_signals()
        action, reason = self.policy.decide(signals)
        if action == "hold" or self._cooling():
            return
        if action == "out":
            self.scale_out(reason=reason, signals=signals)
        elif action == "in":
            self.scale_in(reason=reason, signals=signals)

    # -- telemetry -------------------------------------------------------
    def _decide(self, action: str, reason: str, pilot: str,
                signals: Optional[ScalingSignals],
                detail: Optional[dict] = None) -> None:
        d = ScalingDecision(
            t=time.time(), action=action, reason=reason, pilot=pilot,
            signals=signals.asdict() if signals is not None else {},
            detail=detail or {})
        with self._lock:
            self.decisions.append(d)
            if len(self.decisions) > _MAX_DECISIONS:
                del self.decisions[:len(self.decisions) - _MAX_DECISIONS]
            if action.startswith("reject"):
                self.counters["rejects"] += 1

    def stats(self) -> dict:
        policy = getattr(self.session.manager, "policy", None)
        with self._lock:
            out = {
                "min_pilots": self.min_pilots,
                "max_pilots": self.max_pilots,
                "policy": self.policy.name,
                "running": len(self._running_pilots()),
                "counters": dict(self.counters),
                "last_signals": (self._last_signals.asdict()
                                 if self._last_signals is not None else {}),
                "decisions": [dataclasses.asdict(d)
                              for d in self.decisions],
            }
        out["draining"] = (sorted(policy.draining)
                           if policy is not None else [])
        return out

    # -- scale-out -------------------------------------------------------
    def scale_out(self, n: int = 1, reason: str = "manual",
                  signals: Optional[ScalingSignals] = None
                  ) -> List[PilotCompute]:
        """Provision up to `n` pilots cloned from the template
        description, clamped by ``max_pilots`` and the backend's
        ``capacity()``.  Returns the pilots actually added (possibly
        empty); every outcome is recorded as a decision."""
        from repro.core.backends.base import get_backend
        if signals is None:
            signals = self.collect_signals()
        added: List[PilotCompute] = []
        for _ in range(max(1, int(n))):
            with self._scale_lock:
                running = self._running_pilots()
                if len(running) >= self.max_pilots:
                    self._decide("reject-out",
                                 f"at max_pilots={self.max_pilots}",
                                 "", signals)
                    break
                desc = self.template or (running[0].desc if running
                                         else None)
                if desc is None:
                    self._decide("reject-out",
                                 "no template description and no running "
                                 "pilot to clone", "", signals)
                    break
                try:
                    cap = get_backend(desc.backend).capacity()
                except Exception:   # noqa: BLE001 - unknown adaptor
                    cap = None
                if cap is not None and cap < 1:
                    self._decide("reject-out",
                                 f"backend {desc.backend!r} at capacity",
                                 "", signals)
                    break
                try:
                    pilot = self.session.add_pilot(desc)
                except RuntimeError:    # session closed under us
                    break
                with self._lock:
                    self.counters["scale_outs"] += 1
                self._last_action_t = time.monotonic()
                self._decide("scale-out", reason, pilot.id, signals)
                added.append(pilot)
        return added

    # -- scale-in (the drain protocol) -----------------------------------
    def _pick_victim(self, running: List[PilotCompute]
                     ) -> Optional[PilotCompute]:
        """Least-loaded healthy pilot that nobody else is handling:
        never a quarantined/suspect pilot, never one whose death the
        supervisor is already respawning (a scale-in racing a chaos kill
        must pick a DISTINCT victim), never one already draining."""
        policy = self.session.manager.policy
        bad = set(policy.quarantined) | set(getattr(policy, "draining",
                                                    frozenset()))
        sup = getattr(self.session, "supervisor", None)
        if sup is not None:
            bad |= set(sup.quarantined) | set(sup.handled)
        cands = [p for p in running if p.id not in bad]
        if not cands:
            return None
        pds = self.session.data_service
        cands.sort(key=lambda p: (p.utilization,
                                  pds.holder_load(p.id)["nbytes"], p.id))
        return cands[0]

    def scale_in(self, victim: Optional[PilotCompute] = None,
                 reason: str = "manual",
                 signals: Optional[ScalingSignals] = None
                 ) -> Optional[PilotCompute]:
        """Drain and release one pilot (the least-loaded eligible one
        unless `victim` is given).  Returns the released pilot, or None
        when nothing was released (at the floor, no eligible victim, or
        the drain aborted — each recorded as a decision)."""
        if signals is None:
            signals = self.collect_signals()
        with self._scale_lock:
            running = self._running_pilots()
            if len(running) <= self.min_pilots:
                self._decide("reject-in",
                             f"at min_pilots={self.min_pilots}", "",
                             signals)
                return None
            if victim is None:
                victim = self._pick_victim(running)
            if victim is None:
                self._decide("reject-in", "no eligible victim "
                             "(quarantined/handled/draining excluded)",
                             "", signals)
                return None
            return self._drain_and_release(victim, reason, signals)

    def _drain_and_release(self, victim: PilotCompute, reason: str,
                           signals: ScalingSignals
                           ) -> Optional[PilotCompute]:
        policy = self.session.manager.policy
        policy.drain(victim.id)
        detail: dict = {"serving_handoff": 0, "evacuated": {}}
        try:
            # 1. serving handoff: retire the victim's replica exactly
            # like the reaper retires a dead one — owed requests recover
            # from durable KV pages and re-route to survivors
            for eng in list(getattr(self.session, "serving_engines", ())):
                try:
                    detail["serving_handoff"] += eng.drain_replica(
                        victim.id)
                except Exception:   # noqa: BLE001 - engine mid-close
                    pass
            # 2. quiesce: accepted CUs retire, the engine backlog drains
            # (no NEW work lands — eligible() excludes draining pilots)
            deadline = time.monotonic() + self.drain_timeout_s
            victim.wait_idle(timeout=max(0.0,
                                         deadline - time.monotonic()))
            pool = getattr(victim, "worker_pool", None)
            while (pool is not None and pool.queue.depth > 0
                   and victim.state is State.RUNNING
                   and time.monotonic() < deadline):
                time.sleep(0.01)
            if victim.state is not State.RUNNING:
                # chaos raced us: the corpse is the supervisor's problem
                with self._lock:
                    self.counters["aborted_drains"] += 1
                self._decide("scale-in-aborted",
                             f"victim died mid-drain ({reason})",
                             victim.id, signals, detail)
                return None
            # 3. migrate or checkpoint-flush every resident partition
            evac = self.session.data_service.evacuate_pilot(victim.id)
            detail["evacuated"] = evac
            if evac.get("failed"):
                with self._lock:
                    self.counters["aborted_drains"] += 1
                self._decide("scale-in-aborted",
                             f"{evac['failed']} partitions not evacuable",
                             victim.id, signals, detail)
                return None
            # 4. release (session forgets it in the supervisor first)
            self.session.release(victim)
            with self._lock:
                self.counters["scale_ins"] += 1
            self._last_action_t = time.monotonic()
            self._decide("scale-in", reason, victim.id, signals, detail)
            return victim
        finally:
            policy.undrain(victim.id)

    def __repr__(self) -> str:
        return (f"Autoscaler(pilots={len(self._running_pilots())}, "
                f"min={self.min_pilots}, max={self.max_pilots}, "
                f"policy={self.policy.name!r}, "
                f"{'running' if self._started and not self._stop.is_set() else 'stopped'})")
