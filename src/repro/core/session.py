"""PilotSession: the unified Pilot-API v2 façade.

The paper's central claim (§3, Fig. 5) is that the Pilot-Abstraction is
ONE API for reasoning about compute/data placement across heterogeneous
infrastructures — yet assembling it by hand takes five objects wired in
the right order (PilotComputeService -> ComputeDataManager ->
PilotDataService -> make_backend -> DataUnit.from_array) and per-test
teardown rituals.  PilotSession is that one API:

    from repro.core import PilotSession

    with PilotSession() as s:
        s.add_pilots(2, memory_gb=0.05)             # provision + register
        du = s.data("points", pts, parts=8)         # home placement, bound
        total = s.map_reduce(du, map_fn, reduce_fn) # replica-aware engine
        res = s.kmeans(du, k=8, iters=3)
    # <- deterministic teardown: in-flight replication drained, checkpoint
    #    writes flushed + manifest fsync'd, TierManagers closed, pilots
    #    released — in that order, every time

One session owns:
  * a PilotComputeService (provision/release across backend adaptors);
  * a ComputeDataManager driving a pluggable SchedulingPolicy (default
    LocalityPolicy; pass `policy=` to plug in your own);
  * a PilotDataService (the distributed Pilot-Data replica layer), with
    an optional shared durable checkpoint home (`checkpoint_dir=`) and
    an optional InterconnectModel (`interconnect=`) enabling cost-
    modelled cross-pilot replica reads;
  * the DataUnits created through `data()` (home placement on session-
    owned backends; `tier="file"` lands them in a session scratch dir).

The v1 objects stay public and unchanged — a session is composition,
not replacement — and `session.compute` / `session.manager` /
`session.data_service` expose them for anything the façade doesn't
cover.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import uuid
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import analytics
from repro.core import mapreduce as _mapreduce
from repro.core.data import DataUnit
from repro.core.manager import ComputeDataManager, PilotComputeService
from repro.core.memory import PROFILES, TierProfile, make_backend
from repro.core.pilot import (ComputeUnit, ComputeUnitDescription,
                              PilotCompute, PilotComputeDescription)
from repro.core.pilotdata import PilotDataService
from repro.core.scheduling import InterconnectModel, SchedulingPolicy
from repro.core.supervisor import PilotSupervisor


class PilotSession:
    """Context-managed façade over the whole Pilot-API (see module doc).

    Parameters
    ----------
    policy: SchedulingPolicy for CU placement (default LocalityPolicy).
    interconnect: InterconnectModel enabling cost-modelled cross-pilot
        replica reads (also handed to a LocalityPolicy built by default).
    checkpoint_dir: shared durable checkpoint home for the session's
        PilotDataService (pilots may additionally name their own).
    prebind_wait_s: default stage-in wait bound stamped onto pilot
        descriptions built from kwargs by `add_pilot` (an explicit
        description always wins).
    history_limit: bound on the scheduler's placement-history window.
    supervise: True makes the session self-healing — a PilotSupervisor
        monitor thread heartbeat-checks every pilot, quarantines suspects
        before any task routes to them, respawns confirmed-dead pilots
        from their own descriptions, and drives replication-factor repair
        for DataUnits declared with `data(..., replication=n)`.  Extra
        keyword knobs go through `supervisor_kwargs` (e.g.
        ``supervisor_kwargs={"interval_s": 0.02}``).
    autoscale: True makes the session elastic — an Autoscaler monitor
        thread grows/shrinks the fleet between `min_pilots` and
        `max_pilots` from live load (task-engine backlog, worker
        utilization, tier pressure, serving queue wait), scaling out by
        cloning the fleet's own description and scaling in through the
        drain protocol (quiesce -> serving handoff -> evacuate every
        resident partition -> release).  Extra knobs go through
        `autoscaler_kwargs` (e.g. ``{"policy": LoadScalingPolicy(...)}``).
    rebalance: True starts a background Rebalancer migrating partitions
        off pressure-skewed pilots onto idle ones, priced by the
        session's InterconnectModel; knobs via `rebalancer_kwargs`.
    """

    def __init__(self, *, policy: Optional[SchedulingPolicy] = None,
                 interconnect: Optional[InterconnectModel] = None,
                 checkpoint_dir: Optional[str] = None,
                 prebind_wait_s: Optional[float] = None,
                 history_limit: int = 1024, name: str = "",
                 supervise: bool = False,
                 supervisor_kwargs: Optional[dict] = None,
                 autoscale: bool = False, min_pilots: int = 1,
                 max_pilots: int = 8,
                 autoscaler_kwargs: Optional[dict] = None,
                 rebalance: bool = False,
                 rebalancer_kwargs: Optional[dict] = None):
        self.name = name or f"session-{uuid.uuid4().hex[:8]}"
        self.interconnect = interconnect
        if policy is None:
            # the default policy sees the same interconnect the data
            # service prices fetches with, so placement and fetch agree
            # on what a "cheap" sibling is
            from repro.core.scheduling import LocalityPolicy
            policy = LocalityPolicy(interconnect=interconnect)
        self.compute = PilotComputeService()
        self.manager = ComputeDataManager(self.compute, policy=policy,
                                          history_limit=history_limit)
        self.data_service = PilotDataService(checkpoint_dir=checkpoint_dir,
                                             interconnect=interconnect)
        self._prebind_wait_s = prebind_wait_s
        self._data: Dict[str, DataUnit] = {}
        self._host_backend = make_backend("host")
        self._scratch: Optional[str] = None
        self._closed = False
        # serving engines register themselves here (ServingEngine.deploy)
        # so the autoscaler can read their queue-wait signal and hand off
        # a draining pilot's replica before release
        self.serving_engines: List = []
        self._supervisor: Optional[PilotSupervisor] = None
        if supervise:
            self._supervisor = PilotSupervisor(
                self, **(supervisor_kwargs or {})).start()
        self._autoscaler = None
        self._rebalancer = None
        if autoscale:
            from repro.core.autoscaler import Autoscaler
            self._autoscaler = Autoscaler(
                self, min_pilots=min_pilots, max_pilots=max_pilots,
                **(autoscaler_kwargs or {})).start()
        if rebalance:
            from repro.core.rebalance import Rebalancer
            self._rebalancer = Rebalancer(
                self, **(rebalancer_kwargs or {})).start()

    @property
    def supervisor(self) -> Optional[PilotSupervisor]:
        return self._supervisor

    @property
    def autoscaler(self):
        return self._autoscaler

    @property
    def rebalancer(self):
        return self._rebalancer

    # -- lifecycle -------------------------------------------------------
    def __enter__(self) -> "PilotSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Deterministic teardown, idempotent: (0) stop the supervisor
        FIRST — its monitor thread joins here, so an in-flight respawn
        finishes or aborts before teardown proceeds and the deliberate
        releases below are never mistaken for deaths — then (1) drain
        in-flight replication and flush every checkpoint write
        (durability barrier), (2) release the pilots — which closes each
        pilot's TierManager: queued stages cancelled, in-flight ones
        landed, stager threads joined — and (3) remove the session
        scratch directory backing file-tier home placements (explicit
        `root=` directories are the caller's and stay)."""
        if self._closed:
            return
        self._closed = True
        # the fleet-resizing loops stop before the supervisor: a drain
        # mid-flight finishes or aborts while the failure detector can
        # still tell a released pilot from a dead one
        if self._autoscaler is not None:
            self._autoscaler.close()
        if self._rebalancer is not None:
            self._rebalancer.close()
        if self._supervisor is not None:
            self._supervisor.close()
        self.data_service.drain(timeout=30)
        self.data_service.close()
        self.compute.cancel_all()
        if self._scratch is not None:
            shutil.rmtree(self._scratch, ignore_errors=True)
            self._scratch = None

    # -- pilots ----------------------------------------------------------
    def add_pilot(self, desc: Optional[PilotComputeDescription] = None,
                  **kwargs) -> PilotCompute:
        """Provision a pilot and (when it carries managed memory) join it
        to the session's data service.  Pass a full description, or the
        description's kwargs directly — nested blocks and flat legacy
        fields both work:

            s.add_pilot(memory_gb=0.5, checkpoint_dir="/ckpt")
            s.add_pilot(PilotComputeDescription(memory=MemoryDescription(
                memory_gb=0.5, eviction_policy="gdsf")))
        """
        if self._closed:
            raise RuntimeError(f"{self.name} is closed")
        if desc is None:
            if (self._prebind_wait_s is not None
                    and "prebind_wait_s" not in kwargs):
                kwargs["prebind_wait_s"] = self._prebind_wait_s
            desc = PilotComputeDescription(**kwargs)
        elif kwargs:
            raise TypeError("add_pilot: pass a description OR kwargs, "
                            "not both")
        pilot = self.compute.submit_pilot(desc)
        if pilot.tier_manager is not None:
            self.data_service.register_pilot(pilot)
        return pilot

    def add_pilots(self, n: int, **kwargs) -> List[PilotCompute]:
        """Provision `n` identically-described pilots."""
        return [self.add_pilot(**kwargs) for _ in range(n)]

    @property
    def pilots(self) -> List[PilotCompute]:
        return list(self.compute.pilots.values())

    def release(self, pilot: PilotCompute) -> None:
        """Release one pilot (its replicas leave the registry first, so
        the scheduler stops crediting it immediately; the supervisor is
        told to forget it first, so a deliberate release is never
        mistaken for a death and respawned)."""
        if self._supervisor is not None:
            self._supervisor.forget(pilot.id)
        self.data_service.unregister_pilot(pilot.id)
        self.compute.release(pilot)

    def respawn_pilot(self, dead: PilotCompute) -> PilotCompute:
        """Replace a dead pilot with a fresh one provisioned from the
        dead pilot's own description: the corpse's replicas leave the
        registry and its resources are released (teardown of a FAILED
        pilot is best-effort), then `add_pilot(dead.desc)` re-provisions
        and re-registers the TierManager with the data service.  Raises
        RuntimeError when the session is closed — the supervisor treats
        that as an aborted respawn."""
        if self._closed:
            raise RuntimeError(f"{self.name} is closed")
        self.data_service.unregister_pilot(dead.id)
        try:
            self.compute.release(dead)
        except Exception:   # noqa: BLE001 - the corpse may be half-dead
            self.compute.pilots.pop(dead.id, None)
        return self.add_pilot(dead.desc)

    # -- data ------------------------------------------------------------
    def _scratch_dir(self) -> str:
        if self._scratch is None:
            self._scratch = tempfile.mkdtemp(prefix=f"{self.name}-")
        return self._scratch

    def data(self, name: str, array, parts: int = 1, *,
             tier: str = "host", affinity: str = "", persist: bool = False,
             replication: int = 0,
             profile: Optional[TierProfile] = None,
             root: Optional[str] = None) -> DataUnit:
        """Create a partitioned DataUnit on the session's home backends
        and bind it to the session's data service (so per-pilot replica
        reads, coherent writes, and replica-aware scheduling all work
        out of the box).

        `tier` picks the home placement ("host" default; "file"/"object"
        land under a session scratch directory unless `root` is given,
        with `profile` optionally simulating the home store's bandwidth —
        e.g. PROFILES["stampede_disk"] for a slow shared filesystem).
        `persist=True` additionally writes the partitions through to the
        session's durable checkpoint home.  `replication=n` declares a
        target live-replica count per partition: the data service's
        repair worker (started by a supervising session) re-replicates
        any partition that falls below it after a pilot loss."""
        if self._closed:
            raise RuntimeError(f"{self.name} is closed")
        if name in self._data:
            raise ValueError(f"DataUnit {name!r} already exists in "
                             f"{self.name} (names are session-unique)")
        backends = {"host": self._host_backend,
                    "device": make_backend("device")}
        if tier in ("file", "object") or root is not None:
            file_tier = tier if tier in ("file", "object") else "file"
            backends[file_tier] = make_backend(
                file_tier, root=root or os.path.join(self._scratch_dir(),
                                                     name),
                profile=profile or PROFILES["native"])
        if tier not in backends:
            raise ValueError(f"data(): unsupported home tier {tier!r} "
                             f"(have {sorted(backends)})")
        du = DataUnit.from_array(name, np.asarray(array), parts, backends,
                                 tier=tier, affinity=affinity)
        self.data_service.register(du, persist=persist,
                                   replication=replication)
        self._data[name] = du
        return du

    def data_parts(self, name: str, parts: Sequence, *, tier: str = "host",
                   affinity: str = "", persist: bool = False,
                   replication: int = 0) -> DataUnit:
        """Create a DataUnit from explicit per-partition arrays — ragged
        shapes allowed — and bind it to the session's data service.

        Where `data()` splits one array on axis 0, this takes the
        partition list as given: model shard leaves (one param leaf per
        partition), per-request KV pages, any heterogeneous collection.
        An empty list is valid — grow it later with
        ``DataUnit.append_partition`` (dynamically-arriving request
        state).  `persist`/`replication` behave exactly as in `data()`."""
        if self._closed:
            raise RuntimeError(f"{self.name} is closed")
        if name in self._data:
            raise ValueError(f"DataUnit {name!r} already exists in "
                             f"{self.name} (names are session-unique)")
        backends = {"host": self._host_backend,
                    "device": make_backend("device")}
        if tier not in backends:
            raise ValueError(f"data_parts(): unsupported home tier "
                             f"{tier!r} (have {sorted(backends)})")
        du = DataUnit.from_partitions(
            name, [np.asarray(p) for p in parts], backends, tier=tier,
            affinity=affinity)
        self.data_service.register(du, persist=persist,
                                   replication=replication)
        self._data[name] = du
        return du

    def get_data(self, name: str) -> DataUnit:
        return self._data[name]

    # -- compute ---------------------------------------------------------
    def run(self, fn, *args, input_data: Sequence = (), affinity: str = "",
            **kwargs) -> ComputeUnit:
        """Submit one Compute-Unit through the data-aware scheduler."""
        return self.manager.run(fn, *args, input_data=input_data,
                                affinity=affinity, **kwargs)

    def submit(self, cu_desc: ComputeUnitDescription, **kw) -> ComputeUnit:
        return self.manager.submit(cu_desc, **kw)

    def submit_tasks(self, items, *, retries: int = 0,
                     timeout: float = 30.0):
        """Batched function-as-task dispatch through the session's
        high-throughput task engine: the whole batch is scored in one
        policy pass and executed on the pilots' resident worker pools.
        Items may be bare callables, ``(fn, args[, kwargs])`` tuples, or
        ``ComputeUnitDescription``s; returns a ``TaskBatch`` whose
        ``results()`` preserves submit order.  ``submit``/``run`` remain
        the single-CU path with full CU semantics."""
        return self.manager.submit_tasks(items, retries=retries,
                                         timeout=timeout)

    def map_reduce(self, du: DataUnit, map_fn, reduce_fn, **kw):
        """The replica-aware pipelined map_reduce engine, bound to this
        session's manager (all map_reduce kwargs pass through)."""
        return _mapreduce.map_reduce(du, map_fn, reduce_fn,
                                     manager=self.manager, **kw)

    def kmeans(self, du: DataUnit, k: int, **kw) -> analytics.KMeansResult:
        """The paper's §4.3 KMeans over this session's scheduler."""
        return analytics.kmeans(du, k, manager=self.manager, **kw)

    # -- telemetry -------------------------------------------------------
    def stats(self) -> dict:
        """One merged view: scheduler lifetime stats, data-service
        counters, per-pilot tier residency — and, when supervised, the
        live recovery picture (heartbeat ages, suspicion levels, the
        quarantine set, respawn events, repair-queue depth, and
        per-partition current-vs-target replication)."""
        from repro.core.buf import STATS as _transport_stats
        out = {"session": self.name,
               "scheduler": self.manager.stats(),
               "data": dict(self.data_service.counters),
               "pilots": self.data_service.stats(),
               # process-wide data-plane movement: bytes served as
               # zero-copy views vs bytes memcpy'd, per-codec counts
               "transport": _transport_stats.snapshot()}
        if self._supervisor is not None:
            out["supervisor"] = self._supervisor.stats()
        if self._autoscaler is not None:
            out["autoscaler"] = self._autoscaler.stats()
        if self._rebalancer is not None:
            out["rebalancer"] = self._rebalancer.stats()
        return out

    def __repr__(self) -> str:
        state = "closed" if self._closed else "open"
        return (f"PilotSession({self.name!r}, pilots="
                f"{len(self.compute.pilots)}, data={len(self._data)}, "
                f"{state})")
