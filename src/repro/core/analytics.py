"""KMeans on Pilot-Data Memory — the paper's §4.3 validation workload.

Each iteration is one map_reduce over the points DU:
  map(points_partition, centroids) -> (partial_sums (K,D), counts (K), sse)
  reduce = elementwise add
The centroids update on the driver (paper: 'the centroids vector changes
each iteration'), while the points DU stays wherever its tier keeps it —
file tier re-reads every iteration (paper's file backend), device tier
keeps points in HBM across iterations (paper's Spark backend, the 212x).

The assignment map is the compute hot-spot; kernels/kmeans provides the
Pallas TPU kernel for it (MXU-tiled distance matmul), with the jnp oracle
used everywhere a TPU is absent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.data import DataUnit
from repro.core.manager import ComputeDataManager
from repro.core.mapreduce import map_reduce
from repro.core.pilot import PilotCompute

# the paper's three scenarios: (points, clusters) with constant points*k
PAPER_SCENARIOS = {
    "i": (1_000_000, 50),
    "ii": (100_000, 500),
    "iii": (10_000, 5_000),
}


def assign_partial(points: jax.Array, centroids: jax.Array):
    """Map phase: nearest-centroid assignment + partial centroid sums.

    points (N,D), centroids (K,D) -> (sums (K,D), counts (K,), sse ()).
    Uses the |x-c|^2 = |x|^2 - 2 x.c + |c|^2 matmul form (MXU-friendly;
    mirrored by the Pallas kernel in repro.kernels.kmeans).
    """
    x = points.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)            # (N,1)
    c2 = jnp.sum(c * c, axis=1)[None, :]                  # (1,K)
    d2 = x2 - 2.0 * (x @ c.T) + c2                        # (N,K)
    idx = jnp.argmin(d2, axis=1)
    one_hot = jax.nn.one_hot(idx, c.shape[0], dtype=jnp.float32)
    sums = one_hot.T @ x                                  # (K,D)
    counts = one_hot.sum(axis=0)                          # (K,)
    sse = jnp.sum(jnp.take_along_axis(d2, idx[:, None], axis=1))
    return sums, counts, sse


def _reduce(a, b):
    return jax.tree.map(lambda u, v: u + v, a, b)


@dataclasses.dataclass
class KMeansResult:
    centroids: np.ndarray
    sse_history: list
    iter_seconds: list
    total_seconds: float
    tier: str


def kmeans(du: DataUnit, k: int, iters: int = 5,
           manager: Optional[ComputeDataManager] = None,
           pilot: Optional[PilotCompute] = None,
           map_fn: Callable = assign_partial,
           seed: int = 0, prefetch_depth: Optional[int] = None,
           pipeline: bool = True) -> KMeansResult:
    """Lloyd's algorithm over a (possibly tiered) points DataUnit.

    prefetch_depth/pipeline tune the pipelined map_reduce engine (None =
    adaptive depth from measured stage/compute times); use pipeline=False
    for the sequential i+1-prefetch baseline."""
    d = int(np.asarray(du.partition(0)).shape[1])
    rng = np.random.default_rng(seed)
    centroids = rng.normal(size=(k, d)).astype(np.float32)
    sse_hist, iter_secs = [], []
    t_start = time.time()
    for _ in range(iters):
        t0 = time.time()
        cent_dev = jnp.asarray(centroids)
        sums, counts, sse = map_reduce(du, map_fn, _reduce, manager=manager,
                                       pilot=pilot, extra_args=(cent_dev,),
                                       prefetch_depth=prefetch_depth,
                                       pipeline=pipeline)
        sums, counts, sse = map(np.asarray, (sums, counts, sse))
        nonempty = counts > 0
        centroids = centroids.copy()
        centroids[nonempty] = sums[nonempty] / counts[nonempty, None]
        sse_hist.append(float(sse))
        iter_secs.append(time.time() - t0)
    return KMeansResult(centroids=centroids, sse_history=sse_hist,
                        iter_seconds=iter_secs,
                        total_seconds=time.time() - t_start, tier=du.tier)


def make_blobs(n: int, k: int, d: int = 8, seed: int = 0,
               spread: float = 0.15) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic clustered data (the experiments' input generator)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)).astype(np.float32)
    labels = rng.integers(0, k, size=n)
    pts = centers[labels] + spread * rng.normal(size=(n, d)).astype(np.float32)
    return pts.astype(np.float32), labels
