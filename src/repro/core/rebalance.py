"""Proactive partition rebalancing: migrate data ahead of the hot spot.

Through PR 9, partitions only moved when a replica *died* (the
supervisor's repair worker) — placement skew from uneven registration
or a grown fleet (autoscaler scale-out lands an empty pilot next to a
full one) persisted until failure.  Xuan et al.'s two-level-storage
work (arXiv:1508.01847) motivates pricing every movement against the
storage hierarchy; this module applies it proactively:

  * detect skew: per-pilot *pressure* = resident partition bytes
    weighted by live worker utilization (a busy pilot's bytes hurt more
    — its workers contend with replica reads);
  * plan: donors above ``skew`` x mean pressure shed their smallest
    partitions first (cheapest wins land earliest) to the
    least-pressured receiver not already holding a replica, each move
    priced by the session's ``InterconnectModel``;
  * execute through the EXISTING ``PilotDataService`` machinery —
    ``replicate`` then ``drop_replica`` — so stripe-locked coherence,
    zero-copy views, and the durable-tier invariants hold for free, and
    the copy lands before the source is dropped (a crash mid-move
    leaves an extra replica, never a missing one).

Quarantined, draining, and avoided pilots are never donors or
receivers: the rebalancer must not read from a suspect or load a pilot
that is on its way out.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

from repro.core.pilot import State

_MAX_LOG = 512


@dataclasses.dataclass
class Migration:
    """One planned partition move (du is the DataUnit name)."""
    du: str
    part: int
    src: str
    dst: str
    nbytes: int
    cost_s: float = 0.0
    status: str = "planned"     # planned | done | skipped | failed


class Rebalancer:
    """Background skew detector + migration planner over a PilotSession.

    ``rebalance_once()`` is the public verb (plan + execute one round);
    ``start()`` runs it periodically.  ``skew`` is the trigger ratio: a
    pilot whose pressure exceeds ``skew`` x the fleet mean donates, up
    to ``max_moves`` migrations per round."""

    def __init__(self, session, *, interval_s: float = 0.5,
                 skew: float = 1.5, max_moves: int = 8,
                 tier: str = "host"):
        if skew <= 1.0:
            raise ValueError(f"skew must be > 1.0, got {skew}")
        self.session = session
        self.interval_s = max(0.01, float(interval_s))
        self.skew = float(skew)
        self.max_moves = max(1, int(max_moves))
        self.tier = tier
        self.counters: Dict[str, int] = {
            "rounds": 0, "migrations": 0, "skipped": 0, "failed": 0,
            "bytes_moved": 0}
        self.migrations: List[dict] = []    # executed-move audit log
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "Rebalancer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="pilot-rebalancer")
        self._thread.start()
        return self

    def close(self, timeout: float = 10.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.rebalance_once()
            except Exception:   # noqa: BLE001 - loop survives teardown
                pass

    # -- eligibility -----------------------------------------------------
    def _eligible(self) -> List:
        """RUNNING pilots minus quarantined (policy + supervisor),
        draining, and data-service-avoided ones."""
        policy = self.session.manager.policy
        pds = self.session.data_service
        bad = set(policy.quarantined)
        bad |= set(getattr(policy, "draining", frozenset()))
        sup = getattr(self.session, "supervisor", None)
        if sup is not None:
            bad |= set(sup.quarantined) | set(sup.handled)
        bad |= set(getattr(pds, "avoided", frozenset()))
        return [p for p in self.session.pilots
                if p.state is State.RUNNING and p.id not in bad]

    # -- planning --------------------------------------------------------
    def plan(self) -> List[Migration]:
        """Plan (do not execute) one round of migrations."""
        pds = self.session.data_service
        pilots = self._eligible()
        if len(pilots) < 2:
            return []
        loads = {p.id: pds.holder_load(p.id) for p in pilots}
        pressure = {p.id: loads[p.id]["nbytes"] * (1.0 + p.utilization)
                    for p in pilots}
        mean = sum(pressure.values()) / len(pressure)
        if mean <= 0:
            return []
        donors = sorted((pid for pid, pr in pressure.items()
                         if pr > self.skew * mean),
                        key=lambda pid: -pressure[pid])
        receivers = {pid for pid, pr in pressure.items() if pr < mean}
        if not donors or not receivers:
            return []
        ic = getattr(self.session, "interconnect", None)
        plan: List[Migration] = []
        for donor in donors:
            held = []   # (nbytes, du, part) the donor holds live
            for du in pds.data_units():
                for i in range(du.num_partitions):
                    if donor not in pds._live_replicas(du, i):
                        continue
                    try:
                        nb = pds.partition_nbytes(du, i)
                    except Exception:   # noqa: BLE001 - metadata miss
                        nb = 0
                    held.append((nb, du, i))
            held.sort(key=lambda t: (t[0], t[1].name, t[2]))
            for nb, du, i in held:
                if len(plan) >= self.max_moves:
                    return plan
                holders = pds._live_replicas(du, i)
                cands = sorted((r for r in receivers
                                if r != donor and r not in holders),
                               key=lambda r: pressure[r])
                if not cands:
                    continue
                dst = cands[0]
                cost = (ic.transfer_cost(donor, dst, nb)
                        if ic is not None else 0.0)
                plan.append(Migration(du=du.name, part=i, src=donor,
                                      dst=dst, nbytes=nb, cost_s=cost))
                # moved bytes shift pressure: keep later picks honest
                w = 1.0 + next(p.utilization for p in pilots
                               if p.id == dst)
                pressure[dst] += nb * w
                pressure[donor] = max(0.0, pressure[donor] - nb * w)
                if pressure[donor] <= self.skew * mean:
                    break
        return plan

    # -- execution -------------------------------------------------------
    def execute(self, plan: List[Migration]) -> List[Migration]:
        """Run a plan through replicate-then-drop.  A source that became
        quarantined/avoided since planning is skipped — never read from
        a suspect."""
        pds = self.session.data_service
        policy = self.session.manager.policy
        dus = {du.name: du for du in pds.data_units()}
        for m in plan:
            bad = (set(policy.quarantined)
                   | set(getattr(policy, "draining", frozenset()))
                   | set(getattr(pds, "avoided", frozenset())))
            du = dus.get(m.du)
            if du is None or m.src in bad or m.dst in bad:
                m.status = "skipped"
                with self._lock:
                    self.counters["skipped"] += 1
                continue
            try:
                pds.replicate(du, m.part, m.dst, self.tier)
                pds.drop_replica(du, m.part, m.src)
            except Exception:   # noqa: BLE001 - capacity/lost races
                m.status = "failed"
                with self._lock:
                    self.counters["failed"] += 1
                continue
            m.status = "done"
            with self._lock:
                self.counters["migrations"] += 1
                self.counters["bytes_moved"] += m.nbytes
                self.migrations.append(dataclasses.asdict(m))
                if len(self.migrations) > _MAX_LOG:
                    del self.migrations[:len(self.migrations) - _MAX_LOG]
        return plan

    def rebalance_once(self) -> List[Migration]:
        with self._lock:
            self.counters["rounds"] += 1
        return self.execute(self.plan())

    # -- observability ---------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "skew": self.skew,
                "max_moves": self.max_moves,
                "tier": self.tier,
                "counters": dict(self.counters),
                "migrations": list(self.migrations),
                "running": self._thread is not None
                           and not self._stop.is_set(),
            }

    def __repr__(self) -> str:
        return (f"Rebalancer(skew={self.skew}, "
                f"moves={self.counters['migrations']}, "
                f"bytes={self.counters['bytes_moved']})")
