"""Pluggable CU-placement policies + the cost-modelled interconnect.

Paper §3.3 / Fig. 5: the Compute-Data-Manager assigns Compute-Units to
Pilots "taking into account the current available Pilots, their
utilization and data locality".  Through PR 4 that sentence was six
hardcoded ``W_*`` constants inside ``manager.py``; this module makes it a
strategy:

  * ``SchedulingPolicy`` — the interface the ComputeDataManager drives:
    ``score(pilot, cu_desc)`` and ``select(pilots, cu_desc)`` (which also
    *returns* the winning score, so the submit path never pays for the
    same scan twice);
  * ``LocalityPolicy`` — the default.  With default ``LocalityWeights``
    it reproduces the historical W_DEVICE/W_AFFINITY/W_HOST/W_CKPT/
    W_LOCAL/W_QUEUE scoring bit-for-bit (asserted by
    tests/test_scheduling.py); non-default weights or a subclass make
    every future policy (rebalancing, utilization-aware placement) a
    plug-in instead of another constant;
  * ``InterconnectModel`` — per-link bandwidth (GB/s) + latency between
    pilots, plus a model of the home/checkpoint re-pull path.  The
    PilotDataService consults it on every fetch: a CU bound to pilot A
    reads a partition from sibling pilot B's replica exactly when the
    modelled link cost beats re-pulling from the home store (the
    ROADMAP's cross-pilot replica reads).  A ``LocalityPolicy`` built
    with an interconnect additionally credits pilots whose missing
    partitions are one cheap link away from a sibling replica
    (``weights.sibling``) — with no interconnect attached that term is
    inert and parity with the historical constants is exact.

The module is dependency-light on purpose (pilots and DataUnits are duck
typed), so policies can be unit-tested without provisioning anything.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

# The historical locality weights (device residency dominates, as
# HBM>host>disk; W_CKPT ranks checkpoint-tier residency below host but
# above absent; W_LOCAL rewards any-tier replica stickiness).  Kept as
# module constants because they are the documented default contract —
# LocalityPolicy() must score exactly what manager.py scored before the
# policy extraction.
W_DEVICE, W_AFFINITY, W_HOST, W_CKPT, W_LOCAL, W_QUEUE = (
    100.0, 10.0, 5.0, 3.0, 2.0, 1.0)
# Sibling-replica credit: only active when a LocalityPolicy carries an
# InterconnectModel, and deliberately below W_LOCAL — a cheap link to
# someone else's replica is better than nothing but never beats holding
# the bytes yourself.
W_SIBLING = 1.0


@dataclasses.dataclass(frozen=True)
class LocalityWeights:
    """The scoring coefficients of LocalityPolicy (defaults = the
    historical constants; every term documented in manager.py's score)."""
    device: float = W_DEVICE
    affinity: float = W_AFFINITY
    host: float = W_HOST
    checkpoint: float = W_CKPT
    local: float = W_LOCAL
    queue: float = W_QUEUE
    sibling: float = W_SIBLING


# guards lazy creation of per-policy quarantine/drain state: SchedulingPolicy
# deliberately has no __init__ (subclasses in the wild don't call super()),
# so the sets are attached on first use under this module lock instead
_QUARANTINE_INIT_LOCK = threading.Lock()


class SchedulingPolicy:
    """Strategy interface for CU-over-pilot placement.

    Implementations score a (pilot, cu_desc) pair; higher wins.  `select`
    is the one call sites use: it returns BOTH the winning pilot and its
    score so the caller can record the decision without re-scoring
    (scoring scans every input DU's partitions, so on the submit hot path
    it scales with pilots x DUs x partitions).

    Every policy additionally carries a *quarantine* set — pilot ids a
    supervisor has marked suspect or dead.  ``eligible()`` filters them
    out and is what every placement call site consults first; it fails
    CLOSED (quarantining the whole fleet yields an empty eligible list,
    making late binding wait for a respawn rather than routing work onto
    a suspect).  Quarantine is reversible: ``readmit()`` lifts it when
    heartbeats resume.

    A parallel *draining* set serves the autoscaler's scale-in protocol:
    ``drain()`` quiesces scheduling on a victim pilot — ``eligible()``
    stops returning it, so no new CU, engine task, or serving request
    routes there — while the pilot itself stays healthy, keeps executing
    its accepted backlog, and keeps serving replica reads until its
    partitions have migrated.  ``undrain()`` lifts it (a drain aborted by
    a racing failure hands the pilot back to normal scheduling)."""

    name = "policy"

    # -- quarantine (supervisor-driven liveness filter) ------------------
    def _qset(self) -> set:
        q = getattr(self, "_quarantined", None)
        if q is None:
            with _QUARANTINE_INIT_LOCK:
                q = getattr(self, "_quarantined", None)
                if q is None:
                    q = set()
                    self._quarantined = q
        return q

    def quarantine(self, pilot_id: str) -> None:
        """Exclude a pilot from placement until readmitted."""
        self._qset().add(pilot_id)

    def readmit(self, pilot_id: str) -> None:
        self._qset().discard(pilot_id)

    @property
    def quarantined(self) -> frozenset:
        return frozenset(self._qset())

    # -- draining (autoscaler-driven scale-in quiesce) -------------------
    def _dset(self) -> set:
        d = getattr(self, "_draining", None)
        if d is None:
            with _QUARANTINE_INIT_LOCK:
                d = getattr(self, "_draining", None)
                if d is None:
                    d = set()
                    self._draining = d
        return d

    def drain(self, pilot_id: str) -> None:
        """Quiesce scheduling on a pilot ahead of scale-in: no new work
        routes to it, but it stays healthy and finishes its backlog."""
        self._dset().add(pilot_id)

    def undrain(self, pilot_id: str) -> None:
        self._dset().discard(pilot_id)

    @property
    def draining(self) -> frozenset:
        return frozenset(self._dset())

    def eligible(self, pilots: Sequence) -> List:
        """`pilots` minus the quarantined and draining ones.  Fails
        closed: may be empty — the caller must wait/retry, never fall
        back to a suspect (or route fresh work onto a draining victim)."""
        q = self._qset()
        d = self._dset()
        if not q and not d:
            return list(pilots)
        return [p for p in pilots if p.id not in q and p.id not in d]

    def score(self, pilot, cu_desc) -> float:
        raise NotImplementedError

    def select(self, pilots: Sequence, cu_desc) -> Tuple[object, float]:
        """Best-scoring pilot and its score (first wins ties, matching the
        historical ``max()`` semantics).  `pilots` must be non-empty."""
        if not pilots:
            raise ValueError("select() needs at least one pilot")
        best, best_s = None, float("-inf")
        for p in pilots:
            s = self.score(p, cu_desc)
            if best is None or s > best_s:
                best, best_s = p, s
        return best, best_s

    # -- batch plane (the task engine's path) ---------------------------
    def score_batch(self, pilot, cu_descs: Sequence) -> List[float]:
        """Scores of `cu_descs` on one pilot.  The default is N single
        scores — bit-for-bit the sequential result — so every policy gets
        the batched surface for free; policies override to amortize
        (LocalityPolicy memoizes identical descriptions)."""
        return [self.score(pilot, d) for d in cu_descs]

    def select_batch(self, pilots: Sequence,
                     cu_descs: Sequence) -> List[Tuple[object, float]]:
        """Placements for a whole batch: one (pilot, score) per
        description, in order.  The default is N sequential ``select``
        calls; LocalityPolicy overrides with one score_batch pass per
        pilot plus an incremental queue-penalty model."""
        return [self.select(pilots, d) for d in cu_descs]


class LocalityPolicy(SchedulingPolicy):
    """The default data-locality policy (see manager.py's module
    docstring for the TPU adaptation of the paper's locality argument).

    Scoring, per input DataUnit:

      * bound to a PilotDataService and the pilot participates: per-pilot
        replica residency — ``device*dev/n + host*host/n + ckpt*ckpt/n +
        local*any/n`` (one registry scan yields all four terms).  When
        this policy carries an InterconnectModel, the partitions the
        pilot does NOT hold but a *sibling* pilot does are additionally
        credited ``sibling * home_cost/(link_cost + home_cost)`` each (a
        cheap link earns most of the weight, an expensive one almost
        none; with no interconnect the term is exactly 0.0 and the score
        is bit-for-bit the historical one);
      * unbound (single-manager) DU: measured device residency (mesh-
        aware), then host/checkpoint residency fractions;
      * bound but the pilot is outside the data service: no credit.

    Plus the affinity bonus and minus the utilization (queue) penalty.
    """

    name = "locality"

    def __init__(self, weights: Optional[LocalityWeights] = None,
                 interconnect: Optional["InterconnectModel"] = None):
        self.weights = weights or LocalityWeights()
        self.interconnect = interconnect
        # partition sizes only feed the cost model, so a stale entry is
        # harmless — memoizing them keeps one select() round from paying
        # pilots x parts x holders metadata lookups for pilot-invariant
        # numbers (the same hot-path argument that removed the submit
        # double-scoring)
        self._nbytes_memo: Dict[Tuple[str, int], int] = {}

    # -- helpers --------------------------------------------------------
    @staticmethod
    def _per_pilot_du(pilot, du):
        """The DU's PilotDataService when this (pilot, du) pair is scored
        per-pilot: the DU must be service-bound and the pilot must be a
        registered replica holder candidate."""
        pds = getattr(du, "pilot_data_service", None)
        if (pds is not None and getattr(pilot, "tier_manager", None)
                is not None and pds.knows(pilot.id)):
            return pds
        return None

    @staticmethod
    def _device_tier_hits(pilot, dus) -> float:
        """Fraction of each (single-manager) DU's partitions actually
        resident on the pilot's devices. With a TierManager the *measured*
        residency is used (a DU whose nominal tier is 'device' but whose
        partitions were demoted under memory pressure earns no device
        credit); without one we fall back to the DU's single tier field."""
        hits = 0.0
        for du in dus:
            frac = du.resident_fraction("device")
            if frac <= 0.0:
                continue
            tm = getattr(du, "tier_manager", None)
            be = (tm.backends if tm is not None else du.backends).get("device")
            mesh = getattr(be, "mesh", None)
            if mesh is None or pilot.mesh is None:
                hits += frac  # device-resident, single address space
            else:
                pilot_devs = {d.id for d in pilot.mesh.devices.flat}
                du_devs = {d.id for d in mesh.devices.flat}
                if du_devs & pilot_devs:
                    hits += frac
        return hits

    def _partition_nbytes(self, pds, du, i: int) -> int:
        memo = self._nbytes_memo
        key = (du.name, i)
        nb = memo.get(key)
        if nb is None:
            nb = pds.partition_nbytes(du, i)
            if len(memo) > 4096:    # unbounded DU churn must not leak
                memo.clear()
            memo[key] = nb
        return nb

    def _sibling_credit(self, pilot, du, pds) -> float:
        """Fraction-weighted credit for the partitions this pilot does
        NOT hold but can reach over the modelled interconnect from a
        sibling's replica more cheaply than from home (0.0 without an
        interconnect — the parity-preserving default)."""
        ic = self.interconnect
        n = du.num_partitions
        if ic is None or not n:
            return 0.0
        credit = 0.0
        for i in range(n):
            key = du._key(i)
            all_holders = pds.holders(key)
            if pilot.id in all_holders:
                continue    # already earning real residency credit
            holders = [pid for pid in all_holders if pid != pilot.id]
            if not holders:
                continue
            nb = self._partition_nbytes(pds, du, i)
            best = min(ic.transfer_cost(pid, pilot.id, nb)
                       for pid in holders)
            home = ic.home_cost(nb)
            if best < home:
                credit += home / (best + home) if best + home > 0 else 1.0
        return credit / n

    # -- the score ------------------------------------------------------
    def score(self, pilot, cu_desc) -> float:
        w = self.weights
        s = 0.0
        shared_dus = []     # DUs scored by global (single-manager) residency
        for du in cu_desc.input_data:
            pds = self._per_pilot_du(pilot, du)
            if pds is not None:
                # per-pilot replica residency: one registry scan yields the
                # device, host, and any-tier-stickiness terms together
                n = du.num_partitions
                if n:
                    res = pds.residency(du, pilot.id)
                    held = sum(res.values())
                    s += w.device * res.get("device", 0) / n
                    s += w.host * res.get("host", 0) / n
                    s += w.checkpoint * res.get("checkpoint", 0) / n
                    s += w.local * held / n
                    if held < n:
                        s += w.sibling * self._sibling_credit(pilot, du, pds)
            elif getattr(du, "pilot_data_service", None) is None:
                shared_dus.append(du)
            # else: replica-managed DU on a pilot outside the data
            # service — it holds nothing, so no locality credit
        s += w.device * self._device_tier_hits(pilot, shared_dus)
        for du in shared_dus:
            n = du.num_partitions
            if n:
                res = du.residency()    # one scan for both colder terms
                s += w.host * res.get("host", 0) / n
                s += w.checkpoint * res.get("checkpoint", 0) / n
        if cu_desc.affinity and cu_desc.affinity == pilot.desc.affinity:
            s += w.affinity
        s -= w.queue * pilot.utilization
        return s

    # -- batch plane ----------------------------------------------------
    @staticmethod
    def _desc_key(cu_desc):
        """Two descriptions with identical input-DU identity and affinity
        score identically (against a fixed pilot state), so one batch pass
        scores each distinct shape once.  Tasks routed through the engine
        overwhelmingly share ONE shape (same DU, same affinity) — that is
        what makes the batch pass O(distinct) instead of O(N)."""
        return (tuple(id(du) for du in cu_desc.input_data), cu_desc.affinity)

    def score_batch(self, pilot, cu_descs: Sequence) -> List[float]:
        """One pilot's scores for the whole batch, memoized by description
        shape — bit-for-bit N single scores while the pilot/replica state
        is fixed (asserted by tests/test_taskengine.py)."""
        memo: Dict[tuple, float] = {}
        out: List[float] = []
        for d in cu_descs:
            k = self._desc_key(d)
            s = memo.get(k)
            if s is None:
                s = memo[k] = self.score(pilot, d)
            out.append(s)
        return out

    def select_batch(self, pilots: Sequence,
                     cu_descs: Sequence) -> List[Tuple[object, float]]:
        """Batch placement in ONE scoring pass per pilot.

        Each pilot scores the batch once (memoized above); per task the
        winner is ``argmax(score - queue_weight * placed_here_so_far)`` —
        the same utilization growth the sequential path would observe as
        its own submissions deepen the winner's queue, modelled
        incrementally instead of re-scored N times.  Equal pilots
        therefore round-robin instead of all N tasks piling onto the
        first (first-wins ties, matching ``select``)."""
        if not pilots:
            raise ValueError("select_batch() needs at least one pilot")
        per_pilot = [self.score_batch(p, cu_descs) for p in pilots]
        wq = self.weights.queue
        extra = [0] * len(pilots)
        out: List[Tuple[object, float]] = []
        for i in range(len(cu_descs)):
            best, best_s = 0, float("-inf")
            for j, scores in enumerate(per_pilot):
                s = scores[i] - wq * extra[j]
                if s > best_s:
                    best, best_s = j, s
            extra[best] += 1
            out.append((pilots[best], best_s))
        return out


# -- the interconnect ----------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Link:
    """One directed pilot-to-pilot (or home) transfer edge."""
    gbps: float                 # bandwidth in GB/s (1e9 bytes per second)
    latency_s: float = 0.0      # fixed per-transfer setup cost

    def __post_init__(self):
        if self.gbps < 0 or self.latency_s < 0:
            raise ValueError(f"Link needs gbps >= 0 and latency_s >= 0, "
                             f"got gbps={self.gbps}, "
                             f"latency_s={self.latency_s}")

    def cost(self, nbytes: int) -> float:
        """Modelled seconds to move `nbytes` over this link."""
        if self.gbps <= 0:
            return float("inf")
        return self.latency_s + nbytes / (self.gbps * 1e9)


class InterconnectModel:
    """Per-link GB/s + latency between pilots, plus the home re-pull path.

    `default` is the link assumed between any pilot pair without an
    explicit `set_link` entry (think: the cluster fabric); `home` models
    re-pulling a partition from the DU's home placement / checkpoint
    store (think: the shared parallel filesystem).  The defaults express
    the usual reason to attach a model at all — node-to-node moves over
    the fabric are cheaper than going back to shared storage — and every
    number is overridable per link.

    ``simulate=True`` makes sibling transfers *charge* their modelled
    cost as wall-clock sleep (capped), mirroring TierProfile.charge, so
    benchmarks can compare topologies without real hardware.
    """

    def __init__(self, default: Link = Link(gbps=12.5, latency_s=5e-5),
                 home: Link = Link(gbps=1.2, latency_s=2e-3),
                 simulate: bool = False, sleep_cap_s: float = 2.0):
        self.default = default
        self.home = home
        self.simulate = simulate
        self.sleep_cap_s = sleep_cap_s
        self._links: Dict[Tuple[str, str], Link] = {}
        self._lock = threading.Lock()

    def set_link(self, src: str, dst: str, gbps: float,
                 latency_s: float = 0.0,
                 symmetric: bool = True) -> "InterconnectModel":
        """Declare the link between two pilots (ids or PilotComputes)."""
        a = src if isinstance(src, str) else src.id
        b = dst if isinstance(dst, str) else dst.id
        link = Link(gbps=gbps, latency_s=latency_s)
        with self._lock:
            self._links[(a, b)] = link
            if symmetric:
                self._links[(b, a)] = link
        return self

    def link(self, src: str, dst: str) -> Link:
        with self._lock:
            return self._links.get((src, dst), self.default)

    def transfer_cost(self, src: str, dst: str, nbytes: int) -> float:
        """Modelled seconds to move `nbytes` from pilot `src` to `dst`."""
        if src == dst:
            return 0.0
        return self.link(src, dst).cost(nbytes)

    def home_cost(self, nbytes: int) -> float:
        """Modelled seconds to re-pull `nbytes` from the home/checkpoint
        store."""
        return self.home.cost(nbytes)

    def charge(self, src: str, dst: str, nbytes: int) -> float:
        """Account one sibling transfer; sleeps the modelled time when
        simulating.  Returns the modelled cost either way."""
        c = self.transfer_cost(src, dst, nbytes)
        if self.simulate and c > 0:
            time.sleep(min(c, self.sleep_cap_s))
        return c

    def __repr__(self) -> str:
        with self._lock:
            n = len(self._links)
        return (f"InterconnectModel(default={self.default}, "
                f"home={self.home}, links={n})")


def make_policy_for(name: str = "locality", **kwargs) -> SchedulingPolicy:
    """Tiny registry-style constructor (mirrors tiering.make_policy)."""
    if name == "locality":
        return LocalityPolicy(**kwargs)
    raise ValueError(f"unknown scheduling policy {name!r}")
