from repro.core.backends.base import ComputeBackend, get_backend
from repro.core.backends.inprocess import InProcessBackend
from repro.core.backends.simulated import SimulatedClusterBackend

__all__ = ["ComputeBackend", "get_backend", "InProcessBackend",
           "SimulatedClusterBackend"]
