"""Simulated-cluster backend: provisioning latency, faults, stragglers.

Plays two roles:
1. The paper's Fig. 6 startup-overhead study: each simulated substrate
   (slurm / yarn / spark / cloud) carries a provisioning-latency model taken
   from the paper's observations (YARN two-stage AM+container allocation is
   the slowest; HPC pilot agent startup next; warm Spark cluster fastest).
2. A fault/straggler harness for the runtime layer: CUs can be delayed
   (straggler) or failed (node loss) by an injected policy, which the
   fault-tolerance tests drive deterministically.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.core.backends.base import ComputeBackend, register_backend
from repro.core.pilot import ComputeUnit, PilotCompute, PilotComputeDescription, State
from repro.launch.mesh import mesh_axis_types

# provisioning latency models (seconds): (fixed, per_device) — scaled down
# 100x from the paper's observed seconds so test suites stay fast; the
# *ratios* between substrates are what Fig. 6 compares.
SUBSTRATES: Dict[str, tuple] = {
    "slurm": (0.20, 0.002),      # HPC scheduler + pilot agent bootstrap
    "yarn": (0.45, 0.004),       # AM container + worker containers (2-stage)
    "mesos": (0.30, 0.003),
    "spark": (0.35, 0.003),      # driver + executors on HPC (Pilot-Hadoop)
    "cloud": (0.60, 0.006),      # VM boot dominates
}


@dataclasses.dataclass
class FaultPolicy:
    fail_cu_ids: frozenset = frozenset()       # CU ids to fail once
    straggle_cu_ids: frozenset = frozenset()   # CU ids to delay
    straggle_seconds: float = 0.5
    fail_devices_at: Optional[int] = None      # fail pilot after N CUs
    lose_memory: bool = False                  # node loss wipes the pilot's
    #                                            volatile tiers (device/host)
    #                                            — only checkpoint survives


class SimulatedPilot(PilotCompute):
    def __init__(self, desc, mesh, policy: FaultPolicy):
        super().__init__(desc, mesh)
        self.policy = policy
        self._failed_once: set = set()

    def _execute(self, cu: ComputeUnit):
        if (self.policy.fail_devices_at is not None
                and self._completed >= self.policy.fail_devices_at
                and self.state == State.RUNNING):
            self.state = State.FAILED  # simulated node loss
            if self.policy.lose_memory and self.tier_manager is not None:
                # a dead node's RAM and HBM are gone; partitions the pilot
                # had demoted to the durable checkpoint tier survive and
                # stay readable (the recovery path the retry tests assert)
                self.tier_manager.lose_volatile()
        if self.state == State.FAILED:
            cu.state = State.FAILED
            cu.future.set_exception(
                RuntimeError(f"pilot {self.id} lost its devices (simulated)"))
            cu.end_time = time.time()
            return
        if cu.id in self.policy.straggle_cu_ids:
            # straggling CU occupies the pilot (visible to the scheduler's
            # utilization score and the straggler monitor)
            cu.start_time = cu.start_time or time.time()
            with self._lock:
                self._running += 1
            try:
                time.sleep(self.policy.straggle_seconds)
            finally:
                with self._lock:
                    self._running -= 1
        if cu.id in self.policy.fail_cu_ids and cu.id not in self._failed_once:
            self._failed_once.add(cu.id)
            cu.state = State.FAILED
            cu.future.set_exception(
                RuntimeError(f"CU {cu.id} failed (simulated)"))
            cu.end_time = time.time()
            with self._lock:
                self._completed += 1
            return
        super()._execute(cu)


class SimulatedClusterBackend(ComputeBackend):
    name = "simulated"

    def __init__(self, substrate: str = "yarn",
                 policy: Optional[FaultPolicy] = None, use_devices: bool = True):
        self.substrate = substrate
        self.policy = policy or FaultPolicy()
        self.use_devices = use_devices

    def provision(self, desc: PilotComputeDescription) -> PilotCompute:
        t0 = time.time()
        fixed, per_dev = SUBSTRATES.get(self.substrate, (0.2, 0.002))
        wait = desc.startup_seconds or (fixed + per_dev * desc.num_devices)
        time.sleep(min(wait, 2.0))
        mesh = None
        if self.use_devices:
            n = max(1, min(desc.num_devices, jax.device_count()))
            devices = jax.devices()[:n]
            mesh = jax.sharding.Mesh(np.array(devices), ("data",),
                                     **mesh_axis_types(1))
        pilot = SimulatedPilot(desc, mesh, self.policy)
        # same per-pilot managed memory as the inprocess adaptor (one
        # shared provisioning path in ComputeBackend), so simulated
        # substrates participate in replica-aware scheduling /
        # multi-pilot Pilot-Data exactly like real ones
        self.attach_managed_memory(pilot, desc, mesh=mesh)
        # same shared worker-pool provisioning as inprocess: simulated
        # pilots serve the batched task engine too (fault tests drive it)
        self.attach_worker_pool(pilot, desc)
        pilot.start()
        pilot.provision_time = time.time() - t0
        return pilot


register_backend(SimulatedClusterBackend())
