"""Simulated-cluster backend: provisioning latency, faults, stragglers.

Plays two roles:
1. The paper's Fig. 6 startup-overhead study: each simulated substrate
   (slurm / yarn / spark / cloud) carries a provisioning-latency model taken
   from the paper's observations (YARN two-stage AM+container allocation is
   the slowest; HPC pilot agent startup next; warm Spark cluster fastest).
2. A fault/straggler harness for the runtime layer: CUs can be delayed
   (straggler) or failed (node loss) by an injected policy, which the
   fault-tolerance tests drive deterministically.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.core.backends.base import ComputeBackend, register_backend
from repro.core.pilot import ComputeUnit, PilotCompute, PilotComputeDescription, State
from repro.launch.mesh import mesh_axis_types

# provisioning latency models (seconds): (fixed, per_device) — scaled down
# 100x from the paper's observed seconds so test suites stay fast; the
# *ratios* between substrates are what Fig. 6 compares.
SUBSTRATES: Dict[str, tuple] = {
    "slurm": (0.20, 0.002),      # HPC scheduler + pilot agent bootstrap
    "yarn": (0.45, 0.004),       # AM container + worker containers (2-stage)
    "mesos": (0.30, 0.003),
    "spark": (0.35, 0.003),      # driver + executors on HPC (Pilot-Hadoop)
    "cloud": (0.60, 0.006),      # VM boot dominates
}


@dataclasses.dataclass
class FaultPolicy:
    fail_cu_ids: frozenset = frozenset()       # CU ids to fail once
    straggle_cu_ids: frozenset = frozenset()   # CU ids to delay
    straggle_seconds: float = 0.5
    fail_devices_at: Optional[int] = None      # fail pilot after N CUs
    lose_memory: bool = False                  # node loss wipes the pilot's
    #                                            volatile tiers (device/host)
    #                                            — only checkpoint survives


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault.  `at_s` is relative to the target pilot's own
    start; actions:

      * ``kill``  — the pilot's node dies: state -> FAILED, and (with
        ``lose_memory``) its volatile tiers are wiped.  Permanent.
      * ``stall`` — the pilot looks alive (state RUNNING) but its
        heartbeat freezes for ``duration_s``: the grey failure the phi
        detector exists for.  Heartbeats resume afterwards.
      * ``slow``  — every CU pays an extra ``severity`` seconds while the
        window is open (a degraded node, not a dead one).
    """
    at_s: float
    action: str                  # "kill" | "stall" | "slow"
    duration_s: float = 0.5      # stall/slow window length
    severity: float = 0.05       # slow: extra seconds per CU

    def __post_init__(self):
        if self.action not in ("kill", "stall", "slow"):
            raise ValueError(f"ChaosEvent: unknown action {self.action!r}")


@dataclasses.dataclass
class ChaosPolicy(FaultPolicy):
    """FaultPolicy plus a schedule of pilot-level chaos.  Events apply to
    the `target_index`-th pilot this backend provisions (0-based), so a
    respawned replacement — provisioned later — is never re-targeted and
    recovery can actually converge.  Events fire lazily from the pilot's
    execute path and from every ``health()`` probe; no extra threads."""
    events: tuple = ()           # Tuple[ChaosEvent, ...]
    target_index: int = 0


class SimulatedPilot(PilotCompute):
    def __init__(self, desc, mesh, policy: FaultPolicy):
        super().__init__(desc, mesh)
        self.policy = policy
        self._failed_once: set = set()
        # chaos state: armed by the backend on the target pilot only
        self.chaos_events: tuple = ()
        self._chaos_origin = time.monotonic()
        self._chaos_fired: set = set()
        self._stall_frozen: Optional[float] = None
        self._stall_until: float = 0.0
        self._slow_until: float = 0.0
        self._slow_severity: float = 0.0

    # -- chaos -----------------------------------------------------------
    def arm_chaos(self, events) -> None:
        self.chaos_events = tuple(events)
        self._chaos_origin = time.monotonic()

    def _apply_chaos(self) -> None:
        """Fire every due, unfired event.  Called from the execute path
        and from each health() probe, so a kill lands even on an idle
        pilot (the monitor's probe is what discovers the corpse)."""
        if not self.chaos_events:
            return
        now = time.monotonic()
        elapsed = now - self._chaos_origin
        for i, ev in enumerate(self.chaos_events):
            if i in self._chaos_fired or elapsed < ev.at_s:
                continue
            self._chaos_fired.add(i)
            if ev.action == "kill":
                self.state = State.FAILED
                if self.policy.lose_memory and self.tier_manager is not None:
                    self.tier_manager.lose_volatile()
            elif ev.action == "stall":
                self._stall_frozen = self._last_heartbeat
                self._stall_until = now + ev.duration_s
            elif ev.action == "slow":
                self._slow_until = now + ev.duration_s
                self._slow_severity = ev.severity

    @property
    def last_heartbeat(self) -> float:
        # a stalled pilot's loop keeps running but its liveness signal
        # freezes — exactly what a wedged remote agent looks like
        if (self._stall_frozen is not None
                and time.monotonic() < self._stall_until):
            return self._stall_frozen
        return self._last_heartbeat

    def _execute(self, cu: ComputeUnit):
        self._apply_chaos()
        if (self.policy.fail_devices_at is not None
                and self._completed >= self.policy.fail_devices_at
                and self.state == State.RUNNING):
            self.state = State.FAILED  # simulated node loss
            if self.policy.lose_memory and self.tier_manager is not None:
                # a dead node's RAM and HBM are gone; partitions the pilot
                # had demoted to the durable checkpoint tier survive and
                # stay readable (the recovery path the retry tests assert)
                self.tier_manager.lose_volatile()
        if self.state == State.FAILED:
            cu.state = State.FAILED
            cu.future.set_exception(
                RuntimeError(f"pilot {self.id} lost its devices (simulated)"))
            cu.end_time = time.monotonic()
            return
        if time.monotonic() < self._slow_until:
            time.sleep(self._slow_severity)     # degraded-node tax per CU
        if cu.id in self.policy.straggle_cu_ids:
            # straggling CU occupies the pilot (visible to the scheduler's
            # utilization score and the straggler monitor)
            cu.start_time = cu.start_time or time.monotonic()
            with self._lock:
                self._running += 1
            try:
                time.sleep(self.policy.straggle_seconds)
            finally:
                with self._lock:
                    self._running -= 1
        if cu.id in self.policy.fail_cu_ids and cu.id not in self._failed_once:
            self._failed_once.add(cu.id)
            cu.state = State.FAILED
            cu.future.set_exception(
                RuntimeError(f"CU {cu.id} failed (simulated)"))
            cu.end_time = time.monotonic()
            return
        super()._execute(cu)


class SimulatedClusterBackend(ComputeBackend):
    name = "simulated"

    def __init__(self, substrate: str = "yarn",
                 policy: Optional[FaultPolicy] = None, use_devices: bool = True,
                 max_pilots: Optional[int] = None):
        self.substrate = substrate
        self.policy = policy or FaultPolicy()
        self.use_devices = use_devices
        self.max_pilots = max_pilots     # simulated queue/allocation limit
        self._provisioned = 0    # chaos targeting is by provision order

    def capacity(self):
        """Remaining simulated allocation (LRMS queue limit), counted by
        lifetime provisions like chaos targeting; None = unbounded."""
        if self.max_pilots is None:
            return None
        return max(0, self.max_pilots - self._provisioned)

    def provision(self, desc: PilotComputeDescription) -> PilotCompute:
        t0 = time.time()
        fixed, per_dev = SUBSTRATES.get(self.substrate, (0.2, 0.002))
        wait = desc.startup_seconds or (fixed + per_dev * desc.num_devices)
        time.sleep(min(wait, 2.0))
        mesh = None
        if self.use_devices:
            n = max(1, min(desc.num_devices, jax.device_count()))
            devices = jax.devices()[:n]
            mesh = jax.sharding.Mesh(np.array(devices), ("data",),
                                     **mesh_axis_types(1))
        pilot = SimulatedPilot(desc, mesh, self.policy)
        # same per-pilot managed memory as the inprocess adaptor (one
        # shared provisioning path in ComputeBackend), so simulated
        # substrates participate in replica-aware scheduling /
        # multi-pilot Pilot-Data exactly like real ones
        self.attach_managed_memory(pilot, desc, mesh=mesh)
        # same shared worker-pool provisioning as inprocess: simulated
        # pilots serve the batched task engine too (fault tests drive it)
        self.attach_worker_pool(pilot, desc)
        # chaos schedule applies to exactly the target_index-th provision:
        # the replacement pilot a supervisor respawns is NOT re-targeted
        if (isinstance(self.policy, ChaosPolicy) and self.policy.events
                and self._provisioned == self.policy.target_index):
            pilot.arm_chaos(self.policy.events)
        self._provisioned += 1
        pilot.start()
        pilot.provision_time = time.time() - t0
        return pilot

    def health(self, pilot: PilotCompute) -> dict:
        # fire due chaos first, so the probe itself discovers a scheduled
        # kill/stall even when no CU has touched the pilot
        if isinstance(pilot, SimulatedPilot):
            pilot._apply_chaos()
        return super().health(pilot)


register_backend(SimulatedClusterBackend())
