"""In-process backend: pilots own a slice of the local jax devices.

This is the 'HPC' adaptor of the paper: the resource manager (here: the
process's device set) hands the pilot a static allocation; the pilot then
multiplexes CUs itself (multi-level scheduling). Device slices are leased so
two pilots never share a chip unless oversubscription is requested.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core.backends.base import ComputeBackend, register_backend
from repro.core.pilot import PilotCompute, PilotComputeDescription
from repro.launch.mesh import make_mesh, mesh_axis_types


class InProcessBackend(ComputeBackend):
    name = "inprocess"

    def __init__(self, oversubscribe: bool = True):
        self._lock = threading.Lock()
        self._leased: set = set()
        self.oversubscribe = oversubscribe

    def _lease(self, n: int) -> List:
        devs = jax.devices()
        with self._lock:
            free = [d for d in devs if d.id not in self._leased]
            if len(free) < n:
                if not self.oversubscribe:
                    raise RuntimeError(
                        f"backend has {len(free)} free devices, need {n}")
                free = devs
            take = free[:n]
            self._leased.update(d.id for d in take)
            return take

    def capacity(self):
        """Free (unleased) devices — the hard scale-out bound when this
        adaptor is not oversubscribing; unbounded (None) when it is."""
        if self.oversubscribe:
            return None
        with self._lock:
            return max(0, jax.device_count() - len(self._leased))

    def provision(self, desc: PilotComputeDescription) -> PilotCompute:
        t0 = time.time()
        n = max(1, min(desc.num_devices, jax.device_count()))
        devices = self._lease(n)
        shape = desc.mesh_shape or (len(devices),)
        axes = desc.mesh_axes[:len(shape)] or ("data",)
        mesh = jax.sharding.Mesh(np.array(devices).reshape(shape), axes,
                                 **mesh_axis_types(len(shape)))
        pilot = PilotCompute(desc, mesh)
        # per-pilot managed memory from desc.memory / desc.durability
        # (volatile budgets + the shared durable spill tier)
        self.attach_managed_memory(pilot, desc, mesh=mesh)
        # resident task-engine workers (lazy threads; see taskengine)
        self.attach_worker_pool(pilot, desc)
        pilot.start()
        pilot.provision_time = time.time() - t0
        return pilot

    def health(self, pilot: PilotCompute) -> dict:
        # in-process pilots share our fate, so the base worker-loop
        # heartbeat is the whole truth; annotate with the device lease so
        # a supervisor can tell a released pilot from a dead one
        h = super().health(pilot)
        if pilot.mesh is not None:
            with self._lock:
                h["devices_leased"] = all(
                    d.id in self._leased for d in pilot.mesh.devices.flat)
        return h

    def release(self, pilot: PilotCompute) -> None:
        super().release(pilot)
        if pilot.mesh is not None:
            with self._lock:
                self._leased.difference_update(
                    d.id for d in pilot.mesh.devices.flat)


register_backend(InProcessBackend())
