"""Backend adaptors: the paper's YARN/Mesos/SAGA adaptor layer.

Each adaptor knows how to *provision* a PilotCompute on its substrate.
The paper's point is that the Pilot-API stays identical across them.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.pilot import PilotCompute, PilotComputeDescription

_REGISTRY: Dict[str, "ComputeBackend"] = {}


class ComputeBackend:
    name: str = "base"

    def provision(self, desc: PilotComputeDescription) -> PilotCompute:
        raise NotImplementedError

    @staticmethod
    def attach_managed_memory(pilot: PilotCompute,
                              desc: PilotComputeDescription,
                              mesh=None) -> PilotCompute:
        """Provision the pilot's retained memory from the description's
        `memory`/`durability` blocks (one TierManager: memory_gb ->
        device budget, host_memory_gb -> host budget, checkpoint_dir/gb
        -> the durable spill tier shared per directory).  No-op without a
        memory ask.  Shared by every adaptor so all substrates
        participate identically in multi-pilot Pilot-Data."""
        from repro.core.tiering import tier_manager_for_pilot
        tm = tier_manager_for_pilot(desc, mesh=mesh)
        if tm is not None:
            pilot.attach_tier_manager(tm)
        return pilot

    @staticmethod
    def attach_worker_pool(pilot: PilotCompute,
                           desc: PilotComputeDescription) -> PilotCompute:
        """Provision the pilot's resident task-engine worker pool from
        the description's `task_workers` / `dispatch_queue_depth` knobs
        (raptor-style function-as-task executors pinned to this pilot and
        its TierManager).  Threads start lazily on first submit_tasks, so
        an unused pool costs nothing.  Shared by every adaptor, like
        attach_managed_memory."""
        from repro.core.taskengine import WorkerPool
        pilot.worker_pool = WorkerPool(
            pilot,
            workers=getattr(desc, "task_workers", 2),
            queue_depth=getattr(desc, "dispatch_queue_depth", 1024))
        return pilot

    def health(self, pilot: PilotCompute) -> dict:
        """One liveness sample for the failure detector (supervisor.py).

        The contract every adaptor must honor: ``alive`` is the
        substrate's own verdict (terminal pilot state == not alive),
        ``last_heartbeat`` is a *monotonic* stamp advancing while the
        pilot's worker loop runs, and ``busy`` distinguishes a pilot
        stuck inside one long CU (straggler — suspect, never
        phi-confirm dead) from one whose loop went silent.  Adaptors
        with real remote agents override this with their own probe."""
        from repro.core.pilot import State
        state = pilot.state
        pool = pilot.worker_pool
        return {
            "pilot": pilot.id,
            "state": getattr(state, "value", str(state)),
            "alive": state == State.RUNNING,
            "last_heartbeat": pilot.last_heartbeat,
            "heartbeat_age_s": pilot.heartbeat_age(),
            "busy": pilot.utilization > 0,
            "queued": pilot._queue.qsize(),
            # load telemetry for the autoscaler (same probe the failure
            # detector reads, so a stalled adaptor can't look idle)
            "utilization": pilot.utilization,
            "pool_depth": pool.queue.depth if pool is not None else 0,
            "task_workers": getattr(pilot.desc, "task_workers", 0),
        }

    def capacity(self) -> Optional[int]:
        """How many MORE pilots this adaptor can provision right now, or
        None for unknown/unbounded.  The autoscaler consults this before
        scale-out so it never asks a full substrate for a node."""
        return None

    def release(self, pilot: PilotCompute) -> None:
        pilot.cancel()


def register_backend(backend: ComputeBackend):
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> ComputeBackend:
    if name not in _REGISTRY:
        # late import side-effect registration
        from repro.core.backends import inprocess, simulated  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]
