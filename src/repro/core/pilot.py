"""Pilot-Compute: a retained placeholder allocation of accelerator resources.

Paper §3: "A Pilot-Compute allocates a set of computational resources"; CUs
are late-bound onto it without further system-level scheduling. On TPU the
retained resources are (i) a mesh slice (devices), and (ii) *warm state*:
the compiled-executable cache and device-resident weights/data — the paper's
observation that YARN's per-application JVM+AM startup dominates short jobs
maps 1:1 to XLA compile + weight staging, and retaining them is the win.
"""
from __future__ import annotations

import dataclasses
import enum
import queue
import threading
import time
import uuid
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


# default upper bound on waiting for one pre-binding stage-in before running
# the CU against wherever the data currently lives; per-pilot override via
# PilotComputeDescription(prebind_wait_s=...) / PilotSession(prebind_wait_s=.)
_PREBIND_WAIT_S = 120.0

# the worker loop stamps a heartbeat at least this often even when the CU
# queue is empty (the failure detector's liveness signal; see supervisor.py)
_HEARTBEAT_TICK_S = 0.05


class State(str, enum.Enum):
    NEW = "New"
    PENDING = "Pending"
    RUNNING = "Running"
    DONE = "Done"
    FAILED = "Failed"
    CANCELED = "Canceled"


_EVICTION_POLICIES = ("lru", "gdsf")


@dataclasses.dataclass(frozen=True)
class MemoryDescription:
    """The pilot's retained-memory ask (one TierManager's worth).

    `memory_gb` is the YARN-style device-tier (HBM) budget — 0 means the
    pilot gets no managed hierarchy at all; `host_memory_gb` optionally
    bounds the host tier (0 = unbounded).  The remaining knobs tune the
    TierManager built from the ask.
    """
    memory_gb: float = 0.0           # device-tier budget (0 = unmanaged)
    host_memory_gb: float = 0.0      # host-tier budget (0 = unbounded)
    eviction_policy: str = "lru"     # "lru" | "gdsf" for the pilot's tiers
    hysteresis: int = 0              # eviction ping-pong damping (clock ticks)
    stager_workers: int = 2          # TierManager stager pool width (the
    #                                  depth-k pipeline needs >= depth)

    def __post_init__(self):
        if self.memory_gb < 0 or self.host_memory_gb < 0:
            raise ValueError(
                f"MemoryDescription: memory_gb/host_memory_gb must be >= 0 "
                f"(got {self.memory_gb}/{self.host_memory_gb})")
        if self.eviction_policy not in _EVICTION_POLICIES:
            raise ValueError(
                f"MemoryDescription: eviction_policy must be one of "
                f"{_EVICTION_POLICIES}, got {self.eviction_policy!r}")
        if self.hysteresis < 0:
            raise ValueError("MemoryDescription: hysteresis must be >= 0, "
                             f"got {self.hysteresis}")
        if self.stager_workers < 1:
            raise ValueError("MemoryDescription: stager_workers must be "
                             f">= 1, got {self.stager_workers}")


@dataclasses.dataclass(frozen=True)
class DurabilityDescription:
    """The pilot's durable spill/recovery ask.

    `checkpoint_dir` adds the persistent checkpoint tier beneath the
    volatile budgets; pilots naming the same directory share ONE store
    (the recovery home after pilot loss).  `checkpoint_gb` optionally
    bounds it (0 = unbounded) and is meaningless without a directory.
    """
    checkpoint_dir: str = ""
    checkpoint_gb: float = 0.0

    def __post_init__(self):
        if self.checkpoint_gb < 0:
            raise ValueError("DurabilityDescription: checkpoint_gb must be "
                             f">= 0, got {self.checkpoint_gb}")
        if self.checkpoint_gb and not self.checkpoint_dir:
            raise ValueError(
                "DurabilityDescription: checkpoint_gb was set but "
                "checkpoint_dir is empty — a budget needs a directory to "
                "bound")


_MEMORY_FIELDS = tuple(f.name for f in dataclasses.fields(MemoryDescription))
_DURABILITY_FIELDS = tuple(f.name
                           for f in dataclasses.fields(DurabilityDescription))


@dataclasses.dataclass(frozen=True, init=False)
class PilotComputeDescription:
    """What to allocate (the paper's resource description), composed from
    nested sub-descriptions:

        PilotComputeDescription(
            backend="inprocess", num_devices=1,
            memory=MemoryDescription(memory_gb=0.5, eviction_policy="gdsf"),
            durability=DurabilityDescription(checkpoint_dir="/ckpt"))

    The flat legacy spelling (``memory_gb=0.5``, ``checkpoint_dir=...`` as
    direct kwargs) is still accepted — the compat constructor folds flat
    fields into the nested dataclasses, and read access to the flat names
    keeps working through properties — so descriptions written against
    the v1 API run unchanged.  Mixing a nested block with one of its flat
    fields is an error (ambiguous), as is any unknown kwarg.
    """
    backend: str = "inprocess"       # inprocess | simulated  (adaptor name)
    num_devices: int = 1
    mesh_axes: Tuple[str, ...] = ("data",)
    mesh_shape: Tuple[int, ...] = ()
    memory: MemoryDescription = MemoryDescription()
    durability: DurabilityDescription = DurabilityDescription()
    affinity: str = ""               # locality label
    queue_depth: int = 1024
    # simulated-backend knobs (provisioning latency per paper Fig. 6)
    startup_seconds: float = 0.0
    # upper bound on waiting for ONE pre-binding stage-in future before the
    # CU runs against wherever the data currently lives (scheduler config;
    # a stuck stage must delay a CU, never wedge it)
    prebind_wait_s: float = _PREBIND_WAIT_S
    # the pilot's resident task-engine pool (raptor-style function tasks):
    # worker-thread count and the backpressure bound of its dispatch queue
    task_workers: int = 2
    dispatch_queue_depth: int = 1024

    def __init__(self, backend: str = "inprocess", num_devices: int = 1,
                 mesh_axes: Tuple[str, ...] = ("data",),
                 mesh_shape: Tuple[int, ...] = (),
                 memory: Optional[MemoryDescription] = None,
                 durability: Optional[DurabilityDescription] = None,
                 affinity: str = "", queue_depth: int = 1024,
                 startup_seconds: float = 0.0,
                 prebind_wait_s: float = _PREBIND_WAIT_S,
                 task_workers: int = 2, dispatch_queue_depth: int = 1024,
                 **legacy):
        unknown = set(legacy) - set(_MEMORY_FIELDS) - set(_DURABILITY_FIELDS)
        if unknown:
            raise TypeError(
                f"PilotComputeDescription: unknown field(s) "
                f"{sorted(unknown)}; valid flat legacy fields are "
                f"{sorted(_MEMORY_FIELDS + _DURABILITY_FIELDS)}")
        mem_kw = {k: v for k, v in legacy.items() if k in _MEMORY_FIELDS}
        dur_kw = {k: v for k, v in legacy.items() if k in _DURABILITY_FIELDS}
        if memory is None:
            memory = MemoryDescription(**mem_kw)
        elif mem_kw:
            raise ValueError(
                f"PilotComputeDescription: got both memory= and flat "
                f"field(s) {sorted(mem_kw)} — pass one spelling, not both")
        if durability is None:
            durability = DurabilityDescription(**dur_kw)
        elif dur_kw:
            raise ValueError(
                f"PilotComputeDescription: got both durability= and flat "
                f"field(s) {sorted(dur_kw)} — pass one spelling, not both")
        if num_devices < 1:
            raise ValueError("PilotComputeDescription: num_devices must be "
                             f">= 1, got {num_devices}")
        if queue_depth < 1:
            raise ValueError("PilotComputeDescription: queue_depth must be "
                             f">= 1, got {queue_depth}")
        if prebind_wait_s <= 0:
            raise ValueError("PilotComputeDescription: prebind_wait_s must "
                             f"be > 0, got {prebind_wait_s}")
        if task_workers < 1:
            raise ValueError("PilotComputeDescription: task_workers must "
                             f"be >= 1, got {task_workers}")
        if dispatch_queue_depth < 1:
            raise ValueError("PilotComputeDescription: dispatch_queue_depth "
                             f"must be >= 1, got {dispatch_queue_depth}")
        for k, v in (("backend", backend), ("num_devices", num_devices),
                     ("mesh_axes", tuple(mesh_axes)),
                     ("mesh_shape", tuple(mesh_shape)), ("memory", memory),
                     ("durability", durability), ("affinity", affinity),
                     ("queue_depth", queue_depth),
                     ("startup_seconds", startup_seconds),
                     ("prebind_wait_s", prebind_wait_s),
                     ("task_workers", task_workers),
                     ("dispatch_queue_depth", dispatch_queue_depth)):
            object.__setattr__(self, k, v)

    # -- flat legacy read access (v1 compat) ----------------------------
    @property
    def memory_gb(self) -> float:
        return self.memory.memory_gb

    @property
    def host_memory_gb(self) -> float:
        return self.memory.host_memory_gb

    @property
    def eviction_policy(self) -> str:
        return self.memory.eviction_policy

    @property
    def hysteresis(self) -> int:
        return self.memory.hysteresis

    @property
    def stager_workers(self) -> int:
        return self.memory.stager_workers

    @property
    def checkpoint_dir(self) -> str:
        return self.durability.checkpoint_dir

    @property
    def checkpoint_gb(self) -> float:
        return self.durability.checkpoint_gb


@dataclasses.dataclass
class ComputeUnitDescription:
    """A self-contained piece of work (paper's CU: an 'executable')."""
    fn: Callable
    args: Tuple = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    input_data: Sequence[Any] = ()          # DataUnits the CU reads
    prefetch_parts: Optional[Sequence[int]] = None  # partitions of the first
    #                                         input DU the CU reads first
    #                                         (ensure-availability hint)
    stage_inputs: bool = False              # promote cold DUs to host first
    output_tier: Optional[str] = None       # stage result into this tier
    affinity: str = ""
    name: str = ""
    # per-CU override of the pilot's prebind_wait_s (None = pilot default);
    # map_reduce threads its own prebind_wait_s through here
    prebind_wait_s: Optional[float] = None


class ComputeUnit:
    def __init__(self, desc: ComputeUnitDescription):
        self.desc = desc
        self.id = desc.name or f"cu-{uuid.uuid4().hex[:8]}"
        self.state = State.NEW
        self.future: Future = Future()
        self.submit_time: float = 0.0
        self.start_time: float = 0.0
        self.end_time: float = 0.0
        self.pilot_id: Optional[str] = None
        # pre-binding stage-in futures (paper: ensure data availability
        # before the CU starts): the manager queues them at bind time; the
        # pilot waits for them to land before running the CU body
        self.prebind_futures: List[Future] = []

    def result(self, timeout: Optional[float] = None):
        return self.future.result(timeout)

    def wait(self, timeout: Optional[float] = None):
        self.future.exception(timeout)
        return self.state


class PilotCompute:
    """A running pilot: device slice + worker + warm executable cache."""

    def __init__(self, desc: PilotComputeDescription,
                 mesh: Optional[jax.sharding.Mesh], pilot_id: str = ""):
        self.desc = desc
        self.id = pilot_id or f"pilot-{uuid.uuid4().hex[:8]}"
        self.mesh = mesh
        self.state = State.PENDING
        self._queue: "queue.Queue[Optional[ComputeUnit]]" = queue.Queue(
            maxsize=desc.queue_depth)
        self._jit_cache: Dict[Any, Callable] = {}
        self._running = 0
        self._completed = 0
        self._pending = 0            # CUs accepted but not yet finished
        self._lock = threading.Lock()
        self._idle_cond = threading.Condition(self._lock)
        self._worker: Optional[threading.Thread] = None
        # liveness stamp (monotonic): beaten by the worker loop every tick
        # and by task-engine chunks; the supervisor's failure detector reads
        # it through ComputeBackend.health()
        self._last_heartbeat: float = time.monotonic()
        self.provision_time: float = 0.0
        self.failed_devices: set = set()   # runtime fault injection target
        # the pilot's retained in-memory resources (Pilot-Data Memory): a
        # TierManager whose device-tier budget is this pilot's HBM share
        self.tier_manager = None           # Optional[TierManager]
        # the pilot's resident task-engine worker pool (attached by the
        # backend at provision time; lazily by the TaskEngine otherwise)
        self.worker_pool = None            # Optional[taskengine.WorkerPool]

    # ------------------------------------------------------------------
    def start(self):
        self._worker = threading.Thread(target=self._run_loop, daemon=True,
                                        name=f"{self.id}-worker")
        self.state = State.RUNNING
        self._worker.start()
        return self

    def _run_loop(self):
        while True:
            try:
                cu = self._queue.get(timeout=_HEARTBEAT_TICK_S)
            except queue.Empty:
                self.beat()           # idle liveness: still here, just bored
                continue
            if cu is None:
                break
            if cu.state == State.CANCELED:
                self._cu_finished(ran=False)
                continue
            try:
                self._execute(cu)
            finally:
                self._cu_finished(ran=True)
        self.state = State.DONE

    def _cu_finished(self, ran: bool = True):
        """Retire one accepted CU and wake idle-waiters when the last one
        drains.  Lives here (not in _execute) so backend overrides with
        early-return paths can't leak the pending count."""
        with self._idle_cond:
            self._pending -= 1
            if ran:
                self._completed += 1
            if self._pending == 0:
                self._idle_cond.notify_all()
        self.beat()

    # -- liveness --------------------------------------------------------
    def beat(self) -> None:
        """Stamp the heartbeat (monotonic).  Called from the worker loop's
        idle tick, from CU retirement, and from task-engine chunk
        boundaries; a chaos 'stall' fault freezes it."""
        self._last_heartbeat = time.monotonic()

    @property
    def last_heartbeat(self) -> float:
        return self._last_heartbeat

    def heartbeat_age(self) -> float:
        return max(0.0, time.monotonic() - self.last_heartbeat)

    def _execute(self, cu: ComputeUnit):
        cu.state = State.RUNNING
        cu.start_time = time.monotonic()
        with self._lock:
            self._running += 1
        try:
            # pre-binding stage-in: the copies toward this pilot's tiers
            # were queued at bind time and overlapped the queue wait; they
            # must LAND before the CU body runs (refused/raced stages
            # resolve without raising — reads then pull through instead).
            # The wait is bounded per future by the pilot's configured
            # prebind_wait_s, so a wedged stager delays the CU, never
            # hangs it.
            wait_s = getattr(cu.desc, "prebind_wait_s", None)
            if wait_s is None:
                wait_s = getattr(self.desc, "prebind_wait_s",
                                 _PREBIND_WAIT_S)
            for f in cu.prebind_futures:
                try:
                    f.result(timeout=wait_s)
                except Exception:   # noqa: BLE001
                    pass
            # optional stage-in (cache promotion): off by default so cold
            # tiers keep their re-read cost semantics (paper's file backend)
            if cu.desc.stage_inputs:
                for du in cu.desc.input_data:
                    if du.tier in ("file", "object"):
                        du.to_tier("host", delete_source=False)
            if self.mesh is not None:
                with self.mesh:
                    result = cu.desc.fn(*cu.desc.args, **cu.desc.kwargs)
            else:
                result = cu.desc.fn(*cu.desc.args, **cu.desc.kwargs)
            cu.state = State.DONE
            cu.future.set_result(result)
        except Exception as e:  # noqa: BLE001 - CU failure is a state
            cu.state = State.FAILED
            cu.future.set_exception(e)
        finally:
            cu.end_time = time.monotonic()
            with self._lock:
                self._running -= 1

    # ------------------------------------------------------------------
    def submit_cu(self, cu: ComputeUnit) -> ComputeUnit:
        cu.state = State.PENDING
        cu.submit_time = time.monotonic()
        cu.pilot_id = self.id
        with self._lock:
            self._pending += 1
        try:
            self._queue.put(cu)
        except BaseException:
            self._cu_finished(ran=False)
            raise
        return cu

    def jit_cached(self, key, build: Callable[[], Callable]) -> Callable:
        """The retained-executable cache (warm-start across CUs)."""
        if key not in self._jit_cache:
            self._jit_cache[key] = build()
        return self._jit_cache[key]

    def attach_tier_manager(self, tm) -> "PilotCompute":
        self.tier_manager = tm
        return self

    @property
    def retained_memory_bytes(self) -> int:
        """The pilot's retained in-memory allocation: the device-tier budget
        of its TierManager (0 = unbounded/unmanaged)."""
        if self.tier_manager is not None:
            budget = self.tier_manager.budget("device")
            if budget is not None:
                return int(budget)
        return int(self.desc.memory_gb * 2 ** 30)

    @property
    def utilization(self) -> float:
        with self._lock:
            u = self._pending           # accepted CUs: queued + running
        pool = self.worker_pool
        if pool is not None:
            u += pool.queue.depth       # engine backlog counts as load
        return u

    def cancel(self):
        self._queue.put(None)
        if self._worker:
            self._worker.join(timeout=10)
        if self.worker_pool is not None:
            # drain the task-engine pool BEFORE closing the tiers: queued
            # function tasks may still read managed partitions
            self.worker_pool.close()
        if self.tier_manager is not None:
            self.tier_manager.close()   # stop the stager threads
        self.state = State.CANCELED if self.state != State.DONE else self.state

    def wait_idle(self, timeout: float = 60.0):
        """Block until every accepted CU has retired (queued + running ==
        0).  Event-driven: CU retirement notifies the condition, so the
        wait wakes immediately instead of on a poll tick; the deadline is
        monotonic, immune to wall-clock jumps."""
        deadline = time.monotonic() + timeout
        with self._idle_cond:
            while self._pending > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._idle_cond.wait(remaining)
            return True

    def __repr__(self):
        dev = self.mesh.devices.size if self.mesh is not None else 0
        return (f"PilotCompute({self.id}, backend={self.desc.backend!r}, "
                f"devices={dev}, state={self.state.value})")
