"""Raptor-style high-throughput task engine: resident workers, batched dispatch.

Every Compute-Unit submitted through ``ComputeDataManager.submit`` pays the
full per-task scheduling cost — description construction, a manager-lock
pass, replica scoring, a fresh ``Future``/uuid, and a per-CU queue handoff
into the pilot's single worker loop.  That caps task throughput orders of
magnitude below what a function-as-task executor achieves and blocks the
fine-grained analytics the paper's Pilot-Abstraction targets (Luckow et
al., arXiv:1501.05041).  RADICAL-Pilot's raptor master/worker design (and
its Hadoop-on-HPC follow-up, arXiv:1602.00345) shows the fix: *retain* the
resources as resident workers inside the pilot and amortize dispatch over
batches — the paper's "retain and reuse" argument for memory, applied to
scheduling.  This module is that engine:

  * ``WorkerPool`` — resident worker threads pinned to ONE pilot (and
    thereby to its TierManager: a function task reads the pilot's managed
    tiers via :func:`current_pilot` without re-staging), provisioned by
    the backends from ``PilotComputeDescription.task_workers`` /
    ``dispatch_queue_depth`` and drained deterministically on
    ``close()`` — no accepted task is ever lost to shutdown;
  * ``DispatchQueue`` — the pool's backpressure-bounded task queue.  Work
    is accepted in chunks (amortizing one condition-variable pass over
    ``chunk`` tasks, not one per task) and bounded by ``bound`` queued
    tasks: producers block instead of running arbitrarily far ahead of
    the workers.  The accounting contract (``depth == accepted - taken``,
    never a lost or double-taken task, FIFO order) is asserted by the
    property suite in tests/test_tier_invariants.py;
  * ``Task`` / ``TaskBatch`` — the result futures.  A Task is a slotted,
    future-like handle (``result()`` / ``exception()`` / ``done``) that
    costs ~an order of magnitude less than ``uuid4`` + a
    ``concurrent.futures.Future``; waiting is brokered by the batch's
    single condition variable, and ``TaskBatch.wait()`` resolves the
    whole batch through one counter instead of N lock passes;
  * ``TaskEngine`` — the batched submit path driven by
    ``ComputeDataManager.submit_tasks`` / ``PilotSession.submit_tasks``:
    the whole batch is scored in ONE policy pass
    (``SchedulingPolicy.select_batch`` / ``score_batch`` — the default
    matches N single scores bit-for-bit), placement decisions are
    recorded under the manager's per-pilot *sharded* stats locks (the
    same sharding PR 2 applied to read accounting), and failed tasks are
    re-bound onto surviving pilots with the failed pilot excluded —
    exactly the retry semantics ``result_with_retry`` / ``map_reduce
    (retries=)`` established, task-batched.

The engine deliberately bypasses the per-CU amenities (pre-binding
stage-in futures, per-task mesh-context entry): tasks are *functions*;
anything needing full CU semantics keeps using ``submit``.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.pilot import ComputeUnitDescription, State
from repro.core.supervisor import POLL_BACKOFF, REBIND_BACKOFF

# chunk granularity: one DispatchQueue condition pass hands this many tasks
# to a worker (amortizes the queue hop to ~nothing per task while keeping
# multiple workers busy on large batches)
_CHUNK = 256

# the scoring stand-in for a bare-callable task (no data, no affinity): one
# shared immutable description, so policies see a normal CU shape without a
# per-task allocation
_FUNCTION_DESC = ComputeUnitDescription(fn=lambda: None, name="fn-task")

_tls = threading.local()


def current_pilot():
    """The pilot whose resident worker is executing the current task (None
    outside a WorkerPool thread).  Function tasks use this to reach the
    pilot's TierManager / data service and read partitions without
    re-staging — the raptor 'workers live inside the pilot' property."""
    return getattr(_tls, "pilot", None)


def read_partition(du, i: int, device: bool = False):
    """Worker-local zero-copy partition read for function tasks.

    Inside a WorkerPool thread this routes the read through the executing
    pilot's own tiers (per-pilot replica residency, heat recorded in THAT
    pilot's TierManager); outside a pool it falls back to the DU's home
    read.  Either way the bytes come back as the serving tier's read-only
    view (mmap/aliasing/dlpack — repro.core.buf), so a task consuming the
    partition pays no memcpy; tasks that mutate take
    ``du.partition_copy(i)`` instead."""
    pilot = current_pilot()
    if device:
        return du.partition_device(i, pilot=pilot)
    return du.partition(i, pilot=pilot)


class TaskError(RuntimeError):
    """Terminal engine-side task failure (pool closed, pilot lost with no
    retry budget left)."""


# ---------------------------------------------------------------------------
class Task:
    """One function-as-task and its result future (slotted and lean: the
    per-task cost is what the whole engine amortizes).

    Future-like surface: ``result(timeout)``, ``exception(timeout)``,
    ``done`` (final: value or error set), ``pilot_id`` (last binding).
    Retry state (``retries_left`` / ``exclude``) preserves the
    result_with_retry semantics: a re-bound task never lands back on a
    pilot that already failed it unless every healthy pilot has.
    """

    __slots__ = ("fn", "args", "kwargs", "batch", "value", "error", "done",
                 "pilot_id", "retries_left", "exclude", "desc")

    def __init__(self, fn: Callable, args: tuple, kwargs: Optional[dict],
                 batch: "TaskBatch"):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs        # None == no kwargs (cheaper than {})
        self.batch = batch
        self.value = None
        self.error: Optional[BaseException] = None
        self.done = False
        self.pilot_id: Optional[str] = None
        self.retries_left = 0
        self.exclude: Optional[set] = None
        self.desc: Optional[ComputeUnitDescription] = None

    def result(self, timeout: Optional[float] = None):
        if not self.done:
            self.batch._wait_for(self, timeout)
        if self.error is not None:
            raise self.error
        return self.value

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self.done:
            self.batch._wait_for(self, timeout)
        return self.error

    def __repr__(self) -> str:
        state = ("error" if self.error is not None else
                 "done" if self.done else "pending")
        return f"Task({getattr(self.fn, '__name__', 'fn')}, {state})"


class TaskBatch:
    """One submit_tasks() result: the tasks plus a single completion
    counter/condition, so waiting for 10^5 results is one wait, not 10^5
    lock passes."""

    def __init__(self):
        self._cond = threading.Condition()
        self._pending = 0
        self._waiters = 0
        self.tasks: List[Task] = []

    # -- container surface ----------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks)

    def __getitem__(self, i):
        return self.tasks[i]

    @property
    def pending(self) -> int:
        with self._cond:
            return self._pending

    @property
    def done(self) -> bool:
        return self.pending == 0

    # -- completion plumbing (engine-internal) --------------------------
    def _arm(self, tasks: List[Task]) -> None:
        self.tasks = tasks
        self._pending = len(tasks)

    def _done_n(self, n: int) -> None:
        """Account `n` finalized tasks; one lock pass per worker chunk."""
        with self._cond:
            self._pending -= n
            if self._waiters or self._pending <= 0:
                self._cond.notify_all()

    def _wait_for(self, task: Task, timeout: Optional[float]) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._waiters += 1
            try:
                while not task.done:
                    rem = (None if deadline is None
                           else deadline - time.monotonic())
                    if rem is not None and rem <= 0:
                        raise TimeoutError(f"task not done after {timeout}s")
                    self._cond.wait(rem)
            finally:
                self._waiters -= 1

    # -- user surface ----------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until every task is final (value or error); False on
        timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._waiters += 1
            try:
                while self._pending > 0:
                    rem = (None if deadline is None
                           else deadline - time.monotonic())
                    if rem is not None and rem <= 0:
                        return False
                    self._cond.wait(rem)
                return True
            finally:
                self._waiters -= 1

    def results(self, timeout: Optional[float] = None) -> List[Any]:
        """All results in submit order (raises the first task error)."""
        if not self.wait(timeout):
            raise TimeoutError(f"batch not done after {timeout}s")
        return [t.result() for t in self.tasks]

    def __repr__(self) -> str:
        return f"TaskBatch(n={len(self.tasks)}, pending={self.pending})"


# ---------------------------------------------------------------------------
class DispatchQueue:
    """Backpressure-bounded chunked FIFO feeding one pilot's worker pool.

    Accounting contract (the property suite's invariants):

      * ``depth == accepted - taken`` at every instant;
      * ``depth <= bound`` whenever only ``put`` is used (``put_force``
        — the re-bind path, which must never block a worker thread on
        another pool's backpressure — may overshoot by what it forces);
      * every accepted item is taken exactly once, in FIFO order — no
        loss, no duplication, including across ``close()``: a closed
        queue refuses new items but keeps serving the accepted backlog
        until ``take`` returns None (closed AND drained).
    """

    def __init__(self, bound: int = 1024, chunk: int = _CHUNK):
        if bound < 1:
            raise ValueError(f"DispatchQueue: bound must be >= 1, "
                             f"got {bound}")
        if chunk < 1:
            raise ValueError(f"DispatchQueue: chunk must be >= 1, "
                             f"got {chunk}")
        self.bound = bound
        self.chunk = chunk
        self._cond = threading.Condition()
        self._chunks: deque = deque()
        self._depth = 0
        self._accepted = 0
        self._taken = 0
        self._closed = False

    # -- introspection (lock-free reads of ints are GIL-atomic) ----------
    @property
    def depth(self) -> int:
        return self._depth

    @property
    def accepted(self) -> int:
        return self._accepted

    @property
    def taken(self) -> int:
        return self._taken

    @property
    def closed(self) -> bool:
        return self._closed

    # -- producer side ---------------------------------------------------
    def put(self, items: Sequence, timeout: Optional[float] = None) -> int:
        """Accept `items`, blocking while the queue sits at its bound
        (the backpressure producers feel).  Returns how many items were
        accepted — fewer than ``len(items)`` only on close or timeout;
        the accepted prefix is never rolled back."""
        n = len(items)
        deadline = None if timeout is None else time.monotonic() + timeout
        i = 0
        with self._cond:
            while i < n:
                if self._closed:
                    break
                free = self.bound - self._depth
                if free <= 0:
                    rem = (None if deadline is None
                           else deadline - time.monotonic())
                    if rem is not None and rem <= 0:
                        break
                    self._cond.wait(rem)
                    continue
                take = min(free, self.chunk, n - i)
                self._chunks.append(list(items[i:i + take]))
                self._depth += take
                self._accepted += take
                i += take
                self._cond.notify_all()
        return i

    def put_force(self, items: Sequence) -> int:
        """Accept `items` past the bound (refused only when closed).  The
        re-bind path: a worker re-routing a failed task must never block
        on a sibling pool's backpressure (two full pools re-binding into
        each other would deadlock); forced items are bounded by the retry
        budget, not the queue bound."""
        with self._cond:
            if self._closed:
                return 0
            n = len(items)
            for i in range(0, n, self.chunk):
                self._chunks.append(list(items[i:i + self.chunk]))
            self._depth += n
            self._accepted += n
            self._cond.notify_all()
            return n

    # -- consumer side ---------------------------------------------------
    def take(self, timeout: Optional[float] = None) -> Optional[List]:
        """Next chunk; ``[]`` on timeout, ``None`` once closed AND
        drained (the worker shutdown signal)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not self._chunks:
                if self._closed:
                    return None
                rem = (None if deadline is None
                       else deadline - time.monotonic())
                if rem is not None and rem <= 0:
                    return []
                self._cond.wait(rem)
            chunk = self._chunks.popleft()
            self._depth -= len(chunk)
            self._taken += len(chunk)
            self._cond.notify_all()
            return chunk

    def close(self) -> None:
        """Stop accepting; the backlog stays takeable (drain protocol)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def stats(self) -> Dict[str, int]:
        with self._cond:
            return {"depth": self._depth, "accepted": self._accepted,
                    "taken": self._taken, "bound": self.bound,
                    "closed": int(self._closed)}

    def __repr__(self) -> str:
        return (f"DispatchQueue(depth={self._depth}/{self.bound}, "
                f"accepted={self._accepted}, taken={self._taken})")


# ---------------------------------------------------------------------------
class WorkerPool:
    """Resident worker threads pinned to one pilot (raptor's workers).

    Threads start lazily on first submit (a provisioned-but-unused pool
    costs nothing) and are pinned to the pilot for their lifetime:
    :func:`current_pilot` inside a task returns this pool's pilot, so
    function tasks read the pilot's TierManager-managed partitions
    without re-staging.  ``close()`` drains: accepted tasks run to
    completion (or are finalized with an error when the pool never
    started), then the workers join — no accepted task is ever lost.
    """

    def __init__(self, pilot, workers: int = 2, queue_depth: int = 1024,
                 chunk: int = _CHUNK):
        self.pilot = pilot
        self.workers = max(1, int(workers))
        self.queue = DispatchQueue(bound=max(1, int(queue_depth)),
                                   chunk=chunk)
        self.executed = 0           # telemetry (GIL-atomic increments)
        self._threads: List[threading.Thread] = []
        self._residents: List[Tuple[Task, threading.Thread]] = []
        self._lock = threading.Lock()
        self._started = False
        self._engine: Optional["TaskEngine"] = None

    def bind(self, engine: "TaskEngine") -> "WorkerPool":
        """Attach the engine whose retry/re-bind policy failures route
        through (an unbound pool finalizes errors directly)."""
        self._engine = engine
        return self

    # -- lifecycle -------------------------------------------------------
    def ensure_started(self) -> None:
        if self._started:
            return
        with self._lock:
            if self._started:
                return
            pid = getattr(self.pilot, "id", "pool")
            for i in range(self.workers):
                t = threading.Thread(target=self._run, daemon=True,
                                     name=f"{pid}-taskw{i}")
                t.start()
                self._threads.append(t)
            self._started = True

    def submit(self, tasks: Sequence[Task],
               timeout: Optional[float] = None) -> int:
        """Enqueue `tasks` under backpressure; returns accepted count."""
        self.ensure_started()
        return self.queue.put(tasks, timeout)

    def submit_rebound(self, tasks: Sequence[Task]) -> int:
        """Re-bind path: enqueue past the bound (never blocks a worker)."""
        self.ensure_started()
        return self.queue.put_force(tasks)

    def spawn_resident(self, fn: Callable, *args, name: str = "resident",
                       **kwargs) -> Task:
        """Run `fn` as a LONG-LIVED task on its own dedicated thread,
        pinned to this pool's pilot (``current_pilot()`` resolves inside
        it, so the body reads the pilot's tiers like any chunked task).

        Resident tasks are for service loops — a serving engine's
        continuous-batching decode loop, a poller — that would otherwise
        squat on one of the pool's chunked workers forever and starve the
        batch path.  They never re-bind on failure (a loop is not an
        idempotent work item; its owner observes the error through the
        returned Task and runs its own recovery) and they are expected to
        honor their owner's stop signal: ``close()`` joins them bounded
        after the chunked drain.  Raises TaskError once the pool is
        closed."""
        if self.queue.closed:
            raise TaskError(
                f"pool of pilot {getattr(self.pilot, 'id', '?')} is closed")
        batch = TaskBatch()
        t = Task(fn, args, kwargs or None, batch)
        batch._arm([t])
        t.pilot_id = getattr(self.pilot, "id", None)
        th = threading.Thread(
            target=self._run_resident, args=(t,), daemon=True,
            name=f"{getattr(self.pilot, 'id', 'pool')}-{name}")
        with self._lock:
            self._residents.append((t, th))
        th.start()
        return t

    def _run_resident(self, t: Task) -> None:
        _tls.pilot = self.pilot     # pin: current_pilot() inside the loop
        try:
            v = (t.fn(*t.args) if t.kwargs is None
                 else t.fn(*t.args, **t.kwargs))
        except BaseException as e:  # noqa: BLE001 - failure is a state
            _finalize_error(t, e)
        else:
            t.value = v
            t.done = True
            t.batch._done_n(1)
        finally:
            _tls.pilot = None

    def close(self, timeout: float = 30.0) -> None:
        """Drain-and-stop: refuse new work, run the accepted backlog to
        completion, join the workers (chunked, then resident — their
        owners are expected to have signalled them to stop; the join is
        bounded either way).  A never-started pool finalizes any backlog
        inline so no accepted task is left pending."""
        self.queue.close()
        with self._lock:
            residents = list(self._residents)
        if self._started:
            for t in self._threads:
                t.join(timeout)
        else:
            while True:
                chunk = self.queue.take(timeout=0)
                if not chunk:
                    break
                self._execute_chunk(chunk)
        for _t, th in residents:
            th.join(timeout)

    # -- execution -------------------------------------------------------
    def _run(self) -> None:
        _tls.pilot = self.pilot     # pin: current_pilot() inside tasks
        take = self.queue.take
        while True:
            chunk = take()
            if chunk is None:
                break
            self._execute_chunk(chunk)
        _tls.pilot = None

    def _execute_chunk(self, chunk: List[Task]) -> None:
        pilot = self.pilot
        if (pilot is not None
                and getattr(pilot, "state", State.RUNNING)
                is not State.RUNNING):
            # the pilot died with tasks queued: every task re-binds (or
            # finalizes) through the engine's failure path
            err = TaskError(f"pilot {getattr(pilot, 'id', '?')} is "
                            f"{getattr(pilot.state, 'value', pilot.state)}")
            for t in chunk:
                self._task_failed(t, err)
            return
        # the hot loop: per task, one call + two attr writes; batch
        # completion is accounted once per (batch, chunk) run, not per
        # task — this loop is why the engine clears 10^5 tasks/s
        batch = None
        n_ok = 0
        for t in chunk:
            try:
                v = (t.fn(*t.args) if t.kwargs is None
                     else t.fn(*t.args, **t.kwargs))
            except BaseException as e:  # noqa: BLE001 - failure is a state
                if batch is not None and n_ok:
                    batch._done_n(n_ok)
                    n_ok = 0
                self._task_failed(t, e)
                batch = None
                continue
            t.value = v
            t.done = True
            if t.batch is not batch:
                if batch is not None and n_ok:
                    batch._done_n(n_ok)
                batch, n_ok = t.batch, 1
            else:
                n_ok += 1
        if batch is not None and n_ok:
            batch._done_n(n_ok)
        self.executed += len(chunk)
        if pilot is not None and hasattr(pilot, "beat"):
            pilot.beat()    # chunk boundary: the pool vouches for the pilot

    def _task_failed(self, t: Task, exc: BaseException) -> None:
        eng = self._engine
        if eng is not None:
            eng._task_failed(t, exc, self.pilot)
        else:
            _finalize_error(t, exc)

    @property
    def residents(self) -> int:
        """Live resident (long-lived) tasks on this pool."""
        with self._lock:
            return sum(1 for _t, th in self._residents if th.is_alive())

    def __repr__(self) -> str:
        return (f"WorkerPool({getattr(self.pilot, 'id', '?')}, "
                f"workers={self.workers}, started={self._started}, "
                f"queue={self.queue!r})")


def _finalize_error(t: Task, exc: BaseException) -> None:
    t.error = exc
    t.done = True
    t.batch._done_n(1)


# ---------------------------------------------------------------------------
class TaskEngine:
    """The batched dispatch plane over one ComputeDataManager.

    ``submit_tasks`` accepts a list of work items — bare callables,
    ``(fn, args)`` / ``(fn, args, kwargs)`` tuples, or full
    ``ComputeUnitDescription``s — scores the WHOLE batch in one policy
    pass (``SchedulingPolicy.select_batch``), records the placements
    under the manager's per-pilot sharded stats locks, and feeds each
    pilot's resident WorkerPool through its backpressure-bounded
    DispatchQueue.  Failures re-bind onto surviving pilots (failed pilot
    excluded; exclusion resets when every healthy pilot has failed the
    task — result_with_retry's semantics) until the retry budget runs
    out.
    """

    def __init__(self, manager):
        self.manager = manager
        self._lock = threading.Lock()
        self._rr = itertools.count()    # re-bind round-robin cursor

    # -- pools -----------------------------------------------------------
    def pool_for(self, pilot) -> WorkerPool:
        """The pilot's resident pool (provisioned by the backend from the
        description's task_workers/dispatch_queue_depth knobs; created
        here on demand for pilots provisioned before the engine existed),
        bound to this engine's failure policy."""
        pool = getattr(pilot, "worker_pool", None)
        if pool is None:
            with self._lock:
                pool = getattr(pilot, "worker_pool", None)
                if pool is None:
                    desc = getattr(pilot, "desc", None)
                    pool = WorkerPool(
                        pilot,
                        workers=getattr(desc, "task_workers", 2),
                        queue_depth=getattr(desc, "dispatch_queue_depth",
                                            1024))
                    pilot.worker_pool = pool
        if pool._engine is not self:
            pool.bind(self)
        return pool

    def _healthy_pilots(self, timeout: float = 30.0) -> List:
        """Late binding, batch edition: wait (bounded) for >= 1 healthy,
        non-quarantined pilot.  The quarantine filter fails closed — a
        fully-quarantined fleet makes the batch WAIT for the supervisor's
        respawn instead of dispatching onto a suspect; the wait backs off
        with jitter rather than hammering a fixed 10ms tick."""
        service = self.manager.service
        policy = self.manager.policy
        deadline = time.monotonic() + timeout
        attempt = 0
        while True:
            pilots = policy.eligible(service.healthy_pilots())
            if pilots:
                return pilots
            if time.monotonic() > deadline:
                raise TimeoutError("no eligible pilot available (late "
                                   "binding timed out)")
            POLL_BACKOFF.sleep(attempt)
            attempt += 1

    # -- submission ------------------------------------------------------
    def submit_tasks(self, items: Sequence, *, retries: int = 0,
                     timeout: float = 30.0) -> TaskBatch:
        """Batched dispatch of `items`; returns the TaskBatch of result
        futures (submit order).  `retries` is the per-task re-bind budget
        on failure; `timeout` bounds the late-binding wait for a healthy
        pilot."""
        batch = TaskBatch()
        tasks: List[Task] = []
        descs: List[ComputeUnitDescription] = []
        retries = max(0, int(retries))
        for it in items:
            if isinstance(it, ComputeUnitDescription):
                kw = it.kwargs or None
                t = Task(it.fn, tuple(it.args), kw, batch)
                t.desc = it
                descs.append(it)
            elif callable(it):
                t = Task(it, (), None, batch)
                descs.append(_FUNCTION_DESC)
            elif isinstance(it, tuple) and it and callable(it[0]):
                fn = it[0]
                args = tuple(it[1]) if len(it) > 1 else ()
                kw = dict(it[2]) if len(it) > 2 and it[2] else None
                t = Task(fn, args, kw, batch)
                descs.append(_FUNCTION_DESC)
            else:
                raise TypeError(
                    f"submit_tasks: items must be callables, (fn, args[, "
                    f"kwargs]) tuples, or ComputeUnitDescriptions; got "
                    f"{type(it).__name__}")
            t.retries_left = retries
            tasks.append(t)
        batch._arm(tasks)
        if not tasks:
            return batch
        pilots = self._healthy_pilots(timeout)
        # ONE scoring pass for the whole batch (vs one lock-and-scan pass
        # per task on the submit path)
        if len(pilots) == 1:
            pilot = pilots[0]
            score = self.manager.policy.score(pilot, descs[0])
            groups: List[Tuple[Any, float, List[Task]]] = [
                (pilot, score, tasks)]
        else:
            placed = self.manager.policy.select_batch(pilots, descs)
            by_id: Dict[str, Tuple[Any, float, List[Task]]] = {}
            for t, (pilot, score) in zip(tasks, placed):
                g = by_id.get(pilot.id)
                if g is None:
                    g = by_id[pilot.id] = (pilot, score, [])
                g[2].append(t)
            groups = list(by_id.values())
        for pilot, score, group in groups:
            pid = pilot.id
            for t in group:
                t.pilot_id = pid
            self.manager.record_batch(pilot, group, score)
            pool = self.pool_for(pilot)
            accepted = pool.submit(group)
            if accepted < len(group):
                err = TaskError(f"worker pool of pilot {pid} is closed")
                for t in group[accepted:]:
                    _finalize_error(t, err)
        return batch

    def submit_resident(self, fn: Callable, *args, pilot,
                        name: str = "resident", **kwargs) -> Task:
        """Spawn a long-lived task pinned to `pilot` (explicit binding —
        a resident loop is placed by its owner, e.g. a serving engine's
        per-replica decode loop, not scored: it runs where its state
        lives).  The body executes on a dedicated thread of the pilot's
        resident WorkerPool with ``current_pilot()`` set, without ever
        occupying the pool's chunked workers; the returned Task resolves
        when the loop exits (its owner's stop signal, pilot loss, or a
        crash)."""
        if pilot is None:
            raise ValueError("submit_resident: pilot is required")
        return self.pool_for(pilot).spawn_resident(fn, *args, name=name,
                                                   **kwargs)

    # -- failure / re-bind ----------------------------------------------
    def _task_failed(self, t: Task, exc: BaseException, pilot) -> None:
        """result_with_retry, task-batched: re-bind onto a healthy pilot
        that has not failed this task yet (round-robin over candidates);
        when every healthy pilot has failed it the exclusion resets
        rather than stranding the task; an exhausted retry budget (or an
        empty fleet) finalizes the error."""
        if t.retries_left > 0:
            t.retries_left -= 1
            excl = t.exclude
            if excl is None:
                excl = t.exclude = set()
            if pilot is not None:
                excl.add(pilot.id)
            # bounded backoff before re-binding (attempt number == how
            # many pilots have failed this task): an instant re-dispatch
            # against a fleet that just lost a node stampedes survivors
            REBIND_BACKOFF.sleep(max(0, len(excl) - 1))
            pilots = self.manager.policy.eligible(
                self.manager.service.healthy_pilots())
            cands = [p for p in pilots if p.id not in excl]
            if not cands and pilots:
                excl.clear()
                cands = pilots
            if cands:
                target = cands[next(self._rr) % len(cands)]
                t.pilot_id = target.id
                self.manager.record_batch(target, (t,), 0.0)
                if self.pool_for(target).submit_rebound([t]):
                    return
        _finalize_error(t, exc)

    def stats(self) -> Dict[str, dict]:
        """Per-pilot pool telemetry (queue accounting + executed)."""
        out: Dict[str, dict] = {}
        for p in self.manager.service.healthy_pilots():
            pool = getattr(p, "worker_pool", None)
            if pool is not None:
                row = pool.queue.stats()
                row["executed"] = pool.executed
                row["workers"] = pool.workers
                row["residents"] = pool.residents
                out[p.id] = row
        return out
