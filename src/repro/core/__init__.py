"""Pilot-API: the paper's unified abstraction, TPU-native.

v2 (the PilotSession façade — one declarative surface, one lifecycle):

    from repro.core import PilotSession

    with PilotSession() as s:
        s.add_pilots(2, memory_gb=0.05)
        du = s.data("pts", points, parts=8)
        total = s.map_reduce(du, map_fn, reduce_fn)

v1 (the composable objects underneath — still public, still supported):

    from repro.core import (PilotComputeService, PilotComputeDescription,
                            ComputeDataManager, DataUnit, make_backend)

    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription(backend="inprocess",
                                                     num_devices=1))
    manager = ComputeDataManager(svc)
    du = DataUnit.from_array("pts", points, 8, backends, tier="device")
    cu = manager.run(my_fn, input_data=(du,))
    cu.result()
"""
from repro.core.analytics import KMeansResult, assign_partial, kmeans, make_blobs
from repro.core.autoscaler import (Autoscaler, LoadScalingPolicy,
                                   ScalingDecision, ScalingPolicy,
                                   ScalingSignals)
from repro.core.buf import (Buf, STATS as TRANSPORT_STATS, copy_mode,
                            set_zero_copy, zero_copy_enabled)
from repro.core.codecs import (Codec, PickleCodec, RawCodec, decode_file,
                               encoder_for, file_nbytes, register_codec,
                               unregister_codec)
from repro.core.data import DataUnit, DataUnitDescription
from repro.core.manager import ComputeDataManager, PilotComputeService
from repro.core.mapreduce import map_reduce
from repro.core.memory import (CheckpointBackend, DURABLE_TIERS, PROFILES,
                               TIERS, TierProfile, checkpoint_store,
                               make_backend)
from repro.core.pilot import (ComputeUnit, ComputeUnitDescription,
                              DurabilityDescription, MemoryDescription,
                              PilotCompute, PilotComputeDescription, State)
from repro.core.pilotdata import PilotDataService
from repro.core.rebalance import Migration, Rebalancer
from repro.core.scheduling import (InterconnectModel, Link, LocalityPolicy,
                                   LocalityWeights, SchedulingPolicy)
from repro.core.session import PilotSession
from repro.core.supervisor import (Backoff, FailureDetector, PilotSupervisor,
                                   RespawnEvent)
from repro.core.taskengine import (DispatchQueue, Task, TaskBatch,
                                   TaskEngine, TaskError, WorkerPool,
                                   current_pilot, read_partition)
from repro.core.tiering import (CapacityError, EvictionPolicy, GDSFPolicy,
                                LRUPolicy, TierManager, make_policy,
                                make_tier_manager)

__all__ = [
    "DataUnit", "DataUnitDescription", "ComputeDataManager",
    "PilotComputeService", "map_reduce", "PROFILES", "TIERS", "TierProfile",
    "make_backend", "ComputeUnit", "ComputeUnitDescription", "PilotCompute",
    "PilotComputeDescription", "State", "kmeans", "KMeansResult",
    "assign_partial", "make_blobs", "CapacityError", "TierManager",
    "make_tier_manager", "EvictionPolicy", "LRUPolicy", "GDSFPolicy",
    "make_policy", "PilotDataService", "CheckpointBackend",
    "checkpoint_store", "DURABLE_TIERS",
    # Pilot-API v2
    "PilotSession", "MemoryDescription", "DurabilityDescription",
    "SchedulingPolicy", "LocalityPolicy", "LocalityWeights",
    "InterconnectModel", "Link",
    # the high-throughput task engine (raptor-style batched dispatch)
    "TaskEngine", "TaskBatch", "Task", "TaskError", "WorkerPool",
    "DispatchQueue", "current_pilot", "read_partition",
    # the supervision layer (self-healing sessions)
    "PilotSupervisor", "FailureDetector", "Backoff", "RespawnEvent",
    # the elasticity layer (autoscaling + proactive rebalancing)
    "Autoscaler", "ScalingPolicy", "LoadScalingPolicy", "ScalingSignals",
    "ScalingDecision", "Rebalancer", "Migration",
    # the zero-copy data plane (views, codecs, transport counters)
    "Buf", "TRANSPORT_STATS", "copy_mode", "set_zero_copy",
    "zero_copy_enabled", "Codec", "RawCodec", "PickleCodec",
    "register_codec", "unregister_codec", "encoder_for", "decode_file",
    "file_nbytes",
]
