"""Data-Units: named, partitioned datasets with affinity + tier placement.

Paper §3: "A Data-Unit represents a self-contained, related set of data";
Pilot-Data manages DUs across heterogeneous storage, ensures availability
before a Compute-Unit starts, and exposes *affinity labels* so the scheduler
can co-locate compute with data. Here a DU's partitions live in exactly one
tier at a time (file/object/host/device) and can be moved (staged) between
tiers explicitly or by the ComputeDataManager's late-binding placement.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import uuid
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import jax
import numpy as np

from repro.core.memory import StorageBackend, TIERS


@dataclasses.dataclass(frozen=True)
class DataUnitDescription:
    name: str
    affinity: str = ""              # label, e.g. "pilot-0" / "us-east"
    preferred_tier: str = "file"


class DataUnit:
    """A partitioned dataset resident in one storage tier."""

    def __init__(self, description: DataUnitDescription,
                 backends: Dict[str, StorageBackend],
                 num_partitions: int = 0):
        self.description = description
        self.name = description.name or f"du-{uuid.uuid4().hex[:8]}"
        self.backends = backends
        self.num_partitions = num_partitions
        self.tier: str = description.preferred_tier
        self._lock = threading.Lock()
        self.transfer_log: List[dict] = []   # telemetry for benchmarks

    # ------------------------------------------------------------------
    @classmethod
    def from_partitions(cls, name: str, parts: Sequence[np.ndarray],
                        backends: Dict[str, StorageBackend],
                        tier: str = "host", affinity: str = "") -> "DataUnit":
        du = cls(DataUnitDescription(name, affinity, tier), backends,
                 num_partitions=len(parts))
        be = du._backend(tier)
        for i, p in enumerate(parts):
            be.put(du._key(i), np.asarray(p))
        du.tier = tier
        return du

    @classmethod
    def from_array(cls, name: str, arr: np.ndarray, num_partitions: int,
                   backends: Dict[str, StorageBackend], tier: str = "host",
                   affinity: str = "") -> "DataUnit":
        parts = np.array_split(np.asarray(arr), num_partitions, axis=0)
        return cls.from_partitions(name, parts, backends, tier, affinity)

    # ------------------------------------------------------------------
    def _key(self, i: int) -> str:
        return f"{self.name}/part{i:05d}"

    def _backend(self, tier: str) -> StorageBackend:
        if tier not in self.backends:
            raise KeyError(f"DataUnit {self.name}: no backend for tier {tier!r}"
                           f" (have {sorted(self.backends)})")
        return self.backends[tier]

    @property
    def affinity(self) -> str:
        return self.description.affinity

    def partition(self, i: int) -> np.ndarray:
        return self._backend(self.tier).get(self._key(i))

    def partition_device(self, i: int) -> jax.Array:
        be = self._backend(self.tier)
        if hasattr(be, "get_device"):
            return be.get_device(self._key(i))
        return jax.device_put(be.get(self._key(i)))

    def partitions(self) -> Iterable[np.ndarray]:
        for i in range(self.num_partitions):
            yield self.partition(i)

    def nbytes(self) -> int:
        be = self._backend(self.tier)
        return sum(be.nbytes(self._key(i)) for i in range(self.num_partitions))

    # ------------------------------------------------------------------
    def to_tier(self, tier: str, delete_source: bool = True) -> "DataUnit":
        """Stage every partition into another tier (paper: stage-in/out)."""
        if tier == self.tier:
            return self
        src, dst = self._backend(self.tier), self._backend(tier)
        t0 = time.time()
        moved = 0
        with self._lock:
            for i in range(self.num_partitions):
                arr = src.get(self._key(i))
                dst.put(self._key(i), arr)
                moved += int(np.asarray(arr).nbytes)
                if delete_source:
                    src.delete(self._key(i))
            old = self.tier
            self.tier = tier
        self.transfer_log.append({
            "from": old, "to": tier, "bytes": moved,
            "seconds": time.time() - t0})
        return self

    def replicate_to(self, tier: str) -> "DataUnit":
        return self.to_tier(tier, delete_source=False)

    def delete(self) -> None:
        be = self._backend(self.tier)
        for i in range(self.num_partitions):
            be.delete(self._key(i))

    def __repr__(self) -> str:
        return (f"DataUnit({self.name!r}, parts={self.num_partitions}, "
                f"tier={self.tier!r}, affinity={self.affinity!r})")
