"""Data-Units: named, partitioned datasets with affinity + tier placement.

Paper §3: "A Data-Unit represents a self-contained, related set of data";
Pilot-Data manages DUs across heterogeneous storage, ensures availability
before a Compute-Unit starts, and exposes *affinity labels* so the scheduler
can co-locate compute with data. A DU's partitions live in storage tiers
(file/object/host/device) and can be moved (staged) between tiers explicitly
or by the ComputeDataManager's late-binding placement.

With a TierManager attached (repro.core.tiering) the DU becomes part of a
*managed* hierarchy: `tier` is the preferred/nominal placement, but each
partition's actual residency is tracked by the manager, which enforces
capacity budgets, demotes LRU partitions under pressure, promotes hot ones,
and stages asynchronously. Reads always go through the manager so they find
a partition wherever it currently lives and record access heat.

Bound to a PilotDataService (repro.core.pilotdata) the DU additionally
grows *per-pilot replica residency*: a partition can be resident in
several pilots' managed tiers at once.  Pilot-aware reads
(`partition(i, pilot=...)`) hit that pilot's own tiers and pull the
partition through on a miss; `replicate_to_pilot` copies a working set
into a pilot explicitly; writes (`update_partition`) and `delete`
invalidate every replica coherently.  The home placement (this DU's own
`tier_manager`/backends) stays the source of truth the replicas are
pulled from.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import uuid
from concurrent.futures import Future
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import jax
import numpy as np

from repro.core.buf import Buf, materialize, zero_copy_enabled
from repro.core.memory import StorageBackend, TIERS
from repro.core.tiering import TierManager


@dataclasses.dataclass(frozen=True)
class DataUnitDescription:
    name: str
    affinity: str = ""              # label, e.g. "pilot-0" / "us-east"
    preferred_tier: str = "file"


class DataUnit:
    """A partitioned dataset resident in one (managed) storage tier."""

    def __init__(self, description: DataUnitDescription,
                 backends: Dict[str, StorageBackend],
                 num_partitions: int = 0,
                 tier_manager: Optional[TierManager] = None):
        self.description = description
        self.name = description.name or f"du-{uuid.uuid4().hex[:8]}"
        self.backends = backends
        self.num_partitions = num_partitions
        self.tier: str = description.preferred_tier
        self.tier_manager = tier_manager
        self.pilot_data_service = None       # set by PilotDataService.register
        self._lock = threading.Lock()
        self.transfer_log: List[dict] = []   # telemetry for benchmarks

    # ------------------------------------------------------------------
    @classmethod
    def from_partitions(cls, name: str, parts: Sequence[np.ndarray],
                        backends: Dict[str, StorageBackend],
                        tier: str = "host", affinity: str = "",
                        tier_manager: Optional[TierManager] = None
                        ) -> "DataUnit":
        du = cls(DataUnitDescription(name, affinity, tier), backends,
                 num_partitions=len(parts), tier_manager=tier_manager)
        if tier_manager is not None:
            for i, p in enumerate(parts):
                tier_manager.put(du._key(i), np.asarray(p), tier)
        else:
            be = du._backend(tier)
            for i, p in enumerate(parts):
                be.put(du._key(i), np.asarray(p))
        du.tier = tier
        return du

    @classmethod
    def from_array(cls, name: str, arr: np.ndarray, num_partitions: int,
                   backends: Dict[str, StorageBackend], tier: str = "host",
                   affinity: str = "",
                   tier_manager: Optional[TierManager] = None) -> "DataUnit":
        parts = np.array_split(np.asarray(arr), num_partitions, axis=0)
        return cls.from_partitions(name, parts, backends, tier, affinity,
                                   tier_manager=tier_manager)

    # ------------------------------------------------------------------
    def _key(self, i: int) -> str:
        return f"{self.name}/part{i:05d}"

    def _backend(self, tier: str) -> StorageBackend:
        if tier not in self.backends:
            raise KeyError(f"DataUnit {self.name}: no backend for tier {tier!r}"
                           f" (have {sorted(self.backends)})")
        return self.backends[tier]

    @property
    def affinity(self) -> str:
        return self.description.affinity

    def attach_tier_manager(self, tm: TierManager) -> "DataUnit":
        """Adopt this DU's partitions into a managed hierarchy.

        The manager's backends replace the DU's flat backend dict; existing
        partitions are registered (and count against budgets) in place when
        the manager wraps the same backend, else copied into the manager's.
        """
        same = tm.backends.get(self.tier) is self.backends.get(self.tier)
        for i in range(self.num_partitions):
            if same:
                tm.adopt(self._key(i), self.tier)
            else:
                tm.put(self._key(i),
                       self._backend(self.tier).get(self._key(i)), self.tier)
        self.backends = tm.backends
        self.tier_manager = tm
        return self

    def _pilot_route(self, pilot) -> Optional[str]:
        """Resolve a pilot argument (PilotCompute or id string) to a pilot
        id this DU's PilotDataService can serve, else None (home read)."""
        if pilot is None or self.pilot_data_service is None:
            return None
        pid = pilot if isinstance(pilot, str) else getattr(pilot, "id", None)
        if pid is not None and self.pilot_data_service.knows(pid):
            return pid
        return None

    def partition(self, i: int, pilot=None) -> np.ndarray:
        """Partition bytes as a read-only ndarray view (zero-copy: the
        serving tier's mmap/aliasing/dlpack view — see repro.core.buf).
        Mutating callers take `partition_copy` instead."""
        pid = self._pilot_route(pilot)
        if pid is not None:
            return self.pilot_data_service.read(self, i, pid)
        key = self._key(i)
        if self.tier_manager is not None:
            return self.tier_manager.get(key)
        # a concurrent to_tier() moves copy-first/delete-last, so on a miss
        # the partition is guaranteed to exist in some other tier — retry
        for _ in range(8):
            try:
                return self._backend(self.tier).get(key)
            except (KeyError, FileNotFoundError):
                for t in reversed(TIERS):
                    be = self.backends.get(t)
                    if be is None or t == self.tier:
                        continue
                    try:
                        if be.exists(key):
                            return be.get(key)
                    except (KeyError, FileNotFoundError):
                        continue
        raise KeyError(key)

    def partition_buf(self, i: int, pilot=None) -> Buf:
        """Like `partition`, wrapped in a `Buf` carrying provenance (which
        tier/pilot served the bytes) — the view the pipelined stage-in and
        worker-local read paths move end to end."""
        pid = self._pilot_route(pilot)
        if pid is not None:
            arr = self.pilot_data_service.read(self, i, pid)
            return Buf(arr, source=f"pilot:{pid}",
                       owned=not zero_copy_enabled())
        if self.tier_manager is not None:
            return self.tier_manager.get_buf(self._key(i))
        return Buf(self.partition(i), source=self.tier,
                   owned=not zero_copy_enabled())

    def partition_copy(self, i: int, pilot=None) -> np.ndarray:
        """An owned, writable copy of partition `i` — the sanctioned path
        for callers that mutate fetched bytes (records bytes_copied)."""
        return materialize(self.partition(i, pilot=pilot))

    def partition_device(self, i: int, pilot=None) -> jax.Array:
        pid = self._pilot_route(pilot)
        if pid is not None:
            return self.pilot_data_service.read(self, i, pid, device=True)
        if self.tier_manager is not None:
            return self.tier_manager.get_device(self._key(i))
        be = self._backend(self.tier)
        if hasattr(be, "get_device"):
            return be.get_device(self._key(i))
        return jax.device_put(be.get(self._key(i)))

    def partitions(self) -> Iterable[np.ndarray]:
        for i in range(self.num_partitions):
            yield self.partition(i)

    def nbytes(self) -> int:
        return sum(self.partition_nbytes(i)
                   for i in range(self.num_partitions))

    def partition_nbytes(self, i: int) -> int:
        """One partition's size in bytes without pulling its payload
        through a (possibly throttled) tier — TierManager metadata when
        managed, else the home backend's nbytes (FileBackend answers from
        the .npy header).  Used by the interconnect cost model to price
        transfers."""
        key = self._key(i)
        if self.tier_manager is not None:
            return int(self.tier_manager.entry_nbytes(key))
        return int(self._backend(self.tier).nbytes(key))

    # -- managed-hierarchy surface -------------------------------------
    def residency(self) -> Dict[str, int]:
        """Partition count per tier of *actual* residency."""
        if self.tier_manager is None:
            return {self.tier: self.num_partitions}
        out: Dict[str, int] = {}
        for i in range(self.num_partitions):
            t = self.tier_manager.tier_of(self._key(i))
            if t is not None:
                out[t] = out.get(t, 0) + 1
        return out

    def resident_fraction(self, tier: str) -> float:
        if self.num_partitions == 0:
            return 0.0
        if self.tier_manager is None:
            return 1.0 if self.tier == tier else 0.0
        return self.residency().get(tier, 0) / self.num_partitions

    def pin(self) -> "DataUnit":
        """Exempt every partition from eviction (Spark persist() analogue)."""
        if self.tier_manager is not None:
            self.tier_manager.pin([self._key(i)
                                   for i in range(self.num_partitions)])
        return self

    def unpin(self) -> "DataUnit":
        if self.tier_manager is not None:
            self.tier_manager.unpin([self._key(i)
                                     for i in range(self.num_partitions)])
        return self

    def prefetch(self, i: int, tier: str = "host",
                 pilot=None) -> Optional[Future]:
        """Async-stage partition i toward a hotter tier (no-op unmanaged,
        out of range, or already at least that hot).  With `pilot` set and
        the DU bound to a PilotDataService, the stage targets *that pilot's*
        tiers instead (async replication toward the pilot)."""
        if not 0 <= i < self.num_partitions:
            return None
        pid = self._pilot_route(pilot)
        if pid is not None:
            return self.pilot_data_service.replicate_async(self, i, pid, tier)
        if self.tier_manager is None:
            return None
        return self.tier_manager.prefetch(self._key(i), tier)

    def prefetch_window(self, start: int, depth: int, tier: str = "host",
                        wrap: bool = False, pilot=None) -> List[Future]:
        """Issue async prefetches for partitions [start, start+depth) toward
        `tier` (the depth-k pipeline hint). With wrap=True indices cycle
        modulo num_partitions (streaming input pipelines). Returns the
        futures of the stages actually queued."""
        futs: List[Future] = []
        n = self.num_partitions
        if n == 0 or (self.tier_manager is None
                      and self._pilot_route(pilot) is None):
            return futs
        for j in range(depth):
            i = start + j
            if wrap:
                i %= n
            elif i >= n:
                break
            f = self.prefetch(i, tier, pilot=pilot)
            if f is not None:
                futs.append(f)
        return futs

    # -- per-pilot replica surface ---------------------------------------
    def replicate_to_pilot(self, pilot, parts=None, tier: str = "device",
                           pin: bool = False) -> Dict[int, str]:
        """Copy partitions into a pilot's managed tiers (requires binding
        via PilotDataService.register); returns {partition: landed tier}.
        ``pin=True`` exempts the landed replicas from that pilot's
        eviction (model shards must not be churned out by request
        state)."""
        if self.pilot_data_service is None:
            raise RuntimeError(f"DataUnit {self.name}: not bound to a "
                               "PilotDataService")
        pid = pilot if isinstance(pilot, str) else pilot.id
        return self.pilot_data_service.replicate_to_pilot(
            self, pid, parts=parts, tier=tier, pin=pin)

    def replica_residency(self, pilot) -> Dict[str, int]:
        """Partition count per tier inside one pilot (empty if unbound)."""
        pid = self._pilot_route(pilot)
        if pid is None:
            return {}
        return self.pilot_data_service.residency(self, pid)

    def replica_fraction(self, pilot, tier: str = "device") -> float:
        pid = self._pilot_route(pilot)
        if pid is None:
            return 0.0
        return self.pilot_data_service.resident_fraction(self, pid, tier)

    def persist(self, parts=None, flush: bool = False) -> List[int]:
        """Write partitions through to the PilotDataService's durable
        checkpoint home (the recovery source after pilot loss); requires
        binding via `PilotDataService.register`.  Async by default —
        `flush=True` is the durability barrier."""
        if self.pilot_data_service is None:
            raise RuntimeError(f"DataUnit {self.name}: not bound to a "
                               "PilotDataService")
        return self.pilot_data_service.persist(self, parts=parts,
                                               flush=flush)

    def append_partition(self, value) -> int:
        """Grow the DU by one partition and return its index.

        Dynamically-arriving state — e.g. a serving engine's per-request
        KV pages — needs partitions that appear after registration.  The
        new partition lands in the home placement under the DU lock (the
        index is published only after the bytes exist, so a concurrent
        reader iterating ``range(num_partitions)`` never sees a hole),
        and from then on behaves like any other partition: pilot replica
        reads, ``update_partition`` coherence, ``persist`` to the durable
        tier, replication-factor repair."""
        arr = np.asarray(value)
        with self._lock:
            i = self.num_partitions
            key = self._key(i)
            if self.tier_manager is not None:
                self.tier_manager.put(key, arr, self.tier)
            else:
                self._backend(self.tier).put(key, arr)
            self.num_partitions = i + 1
        return i

    def update_partition(self, i: int, value) -> "DataUnit":
        """Coherent write: the new value lands in the home placement and
        every per-pilot replica is invalidated, so a subsequent pilot read
        re-pulls the fresh bytes instead of serving a stale copy."""
        if not 0 <= i < self.num_partitions:
            raise IndexError(f"partition {i} out of range "
                             f"[0, {self.num_partitions})")
        arr = np.asarray(value)
        if self.tier_manager is not None:
            self.tier_manager.put(self._key(i), arr, self.tier)
        else:
            self._backend(self.tier).put(self._key(i), arr)
        if self.pilot_data_service is not None:
            self.pilot_data_service.invalidate(self, i)
        return self

    # ------------------------------------------------------------------
    def to_tier(self, tier: str, delete_source: bool = True) -> "DataUnit":
        """Stage every partition into another tier (paper: stage-in/out)."""
        if tier == self.tier:
            return self
        t0 = time.perf_counter()
        moved = 0
        if self.tier_manager is not None:
            tm = self.tier_manager
            with self._lock:
                for i in range(self.num_partitions):
                    key = self._key(i)
                    tm.stage(key, tier, keep_source=not delete_source)
                    moved += tm.entry_nbytes(key)
                old, self.tier = self.tier, tier
        else:
            src, dst = self._backend(self.tier), self._backend(tier)
            with self._lock:
                for i in range(self.num_partitions):
                    arr = src.get(self._key(i))
                    dst.put(self._key(i), arr)
                    moved += int(arr.nbytes)
                    if delete_source:
                        src.delete(self._key(i))
                old, self.tier = self.tier, tier
        self.transfer_log.append({
            "from": old, "to": tier, "bytes": moved,
            "seconds": time.perf_counter() - t0})
        return self

    def to_tier_async(self, tier: str) -> List[Future]:
        """Queue every partition onto the background stager; returns the
        per-partition futures. `tier` becomes the nominal placement at once;
        reads stay consistent throughout because they follow actual
        residency via the TierManager."""
        if self.tier_manager is None:
            self.to_tier(tier)
            return []
        futs = [self.tier_manager.stage_async(self._key(i), tier)
                for i in range(self.num_partitions)]
        self.tier = tier
        return futs

    def replicate_to(self, tier: str) -> "DataUnit":
        return self.to_tier(tier, delete_source=False)

    def delete(self) -> None:
        # home copy first, replicas second: a pull-through racing the
        # delete can only re-replicate while the home copy still exists,
        # and the trailing invalidation clears any such resurrection — the
        # opposite order would leak an ownerless replica into a pilot's
        # budget forever
        if self.tier_manager is not None:
            for i in range(self.num_partitions):
                self.tier_manager.delete(self._key(i))
        else:
            be = self._backend(self.tier)
            for i in range(self.num_partitions):
                be.delete(self._key(i))
        if self.pilot_data_service is not None:
            # drop_persistent: the durable checkpoint home must not
            # resurrect a deleted DU through the recovery fetch path
            self.pilot_data_service.invalidate(self, drop_persistent=True)

    def __repr__(self) -> str:
        return (f"DataUnit({self.name!r}, parts={self.num_partitions}, "
                f"tier={self.tier!r}, affinity={self.affinity!r})")
