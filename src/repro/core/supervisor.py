"""Self-healing sessions: heartbeat failure detection, respawn, repair.

The paper's core robustness claim (§3) is that the Pilot-Abstraction
decouples system-level resource allocation from application progress:
losing a pilot never loses work past the durable tier.  Through PR 6 the
repo only *reacted* to failure — map_reduce re-bound groups after their
CU raised, but nothing noticed a dead pilot before a task hit it, nothing
replaced the lost capacity (the Hadoop-on-HPC follow-up, arXiv:1602.00345,
makes dynamic re-provisioning the recovery mechanism), and a partition
whose replicas lived on the dead node silently ran at lower redundancy.
This module is the supervision layer that closes those gaps:

  * ``FailureDetector`` — phi-accrual-style suspicion over heartbeats.
    Every pilot's worker loop stamps a monotonic heartbeat (see
    ``PilotCompute.beat``); the backend exposes it through ``health()``.
    The detector keeps an EWMA of observed beat intervals per pilot and
    scores the current silence as ``phi = age / mean_interval`` — a
    unitless suspicion level that self-calibrates to however fast this
    substrate actually beats.  ``phi >= suspect_phi`` quarantines the
    pilot (no new work routed to it, replication repair refuses to read
    from it) and ``phi >= dead_phi`` — or a terminal pilot state —
    confirms death.  A quarantined pilot whose heartbeats resume is
    readmitted: suspicion is a reversible state, death is not.

  * ``PilotSupervisor`` — the monitor thread driving the detector over a
    session (or a bare service+manager pair).  On suspicion it excludes
    the pilot from the ``SchedulingPolicy`` (quarantine) *before* any
    further task is late-bound onto it; on confirmed death it
    re-provisions a replacement from the dead pilot's own
    ``PilotComputeDescription`` through ``PilotSession.add_pilot`` (so
    the new pilot re-registers its TierManager with the data service and
    rejoins scheduling), then readmits the dead id so the registry stays
    clean.  Respawn events are recorded for ``stats()`` and bounded by
    ``max_respawns`` so a crash-looping substrate cannot spin forever.

  * replication-factor repair — delegated to
    ``PilotDataService.start_repair``: DataUnits registered with a target
    ``replication`` are re-replicated from surviving replicas or the
    durable checkpoint tier whenever a pilot loss (or eviction) drops
    them below target.  The supervisor starts/stops the repair worker
    and feeds it the quarantine set so repair never reads a suspect.

  * ``Backoff`` — bounded exponential backoff with full jitter, shared
    by every hardened retry path (``result_with_retry``, the task-engine
    re-bind, map_reduce group retries, late-binding polls) so a fleet of
    retrying clients does not synchronize into thundering herds.

The seed-era ``repro.runtime.fault_tolerance.ResilientRunner`` is rebuilt
on this layer: its release/re-provision step is ``replace_pilot`` here.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, List, Optional

from repro.core.pilot import PilotCompute, State


# -- bounded exponential backoff with jitter --------------------------------
@dataclasses.dataclass(frozen=True)
class Backoff:
    """Delay schedule for retries: ``base * factor**attempt``, capped at
    ``cap``, with full jitter (uniform in [delay*(1-jitter), delay]) so
    concurrent retriers spread out instead of stampeding in lockstep.
    Frozen: one instance is safely shared across threads."""

    base_s: float = 0.01
    cap_s: float = 0.5
    factor: float = 2.0
    jitter: float = 0.5

    def delay(self, attempt: int) -> float:
        """The (jittered) delay before retry number `attempt` (0-based)."""
        d = min(self.cap_s, self.base_s * self.factor ** max(0, attempt))
        if self.jitter <= 0:
            return d
        lo = d * (1.0 - min(1.0, self.jitter))
        return random.uniform(lo, d)

    def sleep(self, attempt: int) -> float:
        d = self.delay(attempt)
        if d > 0:
            time.sleep(d)
        return d


# retry-path defaults: small bases so test suites stay fast, caps bound the
# worst case (a worker thread re-binding a task must never stall its chunk
# for long; a map_reduce attempt can afford a slightly longer breath)
REBIND_BACKOFF = Backoff(base_s=0.005, cap_s=0.1)
RETRY_BACKOFF = Backoff(base_s=0.02, cap_s=0.5)
# late-binding poll: starts near the old fixed 10ms, grows to a bounded tick
POLL_BACKOFF = Backoff(base_s=0.005, cap_s=0.05, jitter=0.3)


# -- failure detection -------------------------------------------------------
class FailureDetector:
    """Phi-accrual-style heartbeat suspicion (per pilot).

    ``observe(pid, last_beat, now)`` feeds one health sample (the pilot's
    most recent monotonic heartbeat stamp); the detector maintains an
    EWMA of the intervals *between distinct beats* it has seen.
    ``phi(pid, now)`` is the current silence measured in units of that
    mean interval — 1.0 means "exactly as late as usual", 4.0 means "4x
    the usual gap".  The floor ``min_interval_s`` keeps a fast-beating
    pilot from tripping on scheduler noise.
    """

    def __init__(self, min_interval_s: float = 0.1, alpha: float = 0.3):
        self.min_interval_s = max(1e-4, float(min_interval_s))
        self.alpha = alpha
        self._last: Dict[str, float] = {}    # pilot -> last beat stamp seen
        self._mean: Dict[str, float] = {}    # pilot -> EWMA beat interval
        self._lock = threading.Lock()

    def observe(self, pid: str, last_beat: float, now: float) -> None:
        with self._lock:
            prev = self._last.get(pid)
            if prev is None:
                self._last[pid] = last_beat
                return
            if last_beat > prev:
                interval = last_beat - prev
                m = self._mean.get(pid)
                self._mean[pid] = (interval if m is None else
                                   (1 - self.alpha) * m
                                   + self.alpha * interval)
                self._last[pid] = last_beat

    def phi(self, pid: str, now: float) -> float:
        with self._lock:
            last = self._last.get(pid)
            if last is None:
                return 0.0
            mean = max(self._mean.get(pid, self.min_interval_s),
                       self.min_interval_s)
        return max(0.0, now - last) / mean

    def forget(self, pid: str) -> None:
        with self._lock:
            self._last.pop(pid, None)
            self._mean.pop(pid, None)


@dataclasses.dataclass
class RespawnEvent:
    """One completed pilot replacement (telemetry for stats())."""
    old_pilot: str
    new_pilot: str      # "" when the respawn was aborted (session closed)
    reason: str         # "state:Failed" | "phi" | "manual" | ...
    downtime_s: float
    t: float            # wall-clock stamp (telemetry only)


class PilotSupervisor:
    """Monitor thread making a pilot fleet self-healing (see module doc).

    Construct over a ``PilotSession`` (the normal path — sessions build
    one with ``supervise=True``) or over bare parts::

        sup = PilotSupervisor(compute=service, manager=manager)

    Knobs
    -----
    interval_s: monitor poll period.
    suspect_phi / dead_phi: suspicion thresholds (units of the pilot's
        own mean heartbeat interval).  A busy pilot stuck in one long CU
        is *suspected* (quarantined) but never phi-confirmed dead while
        it reports ``busy`` — slow work is a straggler problem, not node
        death; terminal pilot *state* confirms death regardless.
    max_respawns: lifetime cap on automatic replacements.
    auto_respawn: False turns the supervisor into detect/quarantine-only
        (the ResilientRunner drives ``replace_pilot`` itself).
    repair_interval_s: period of the data service's replication-repair
        worker (started by ``start()`` when a data service is present).
    """

    def __init__(self, session=None, *, compute=None, manager=None,
                 data_service=None, interval_s: float = 0.05,
                 min_heartbeat_s: float = 0.1,
                 suspect_phi: float = 4.0, dead_phi: float = 30.0,
                 max_respawns: int = 8, auto_respawn: bool = True,
                 repair_interval_s: float = 0.1,
                 backoff: Backoff = RETRY_BACKOFF):
        self.session = session
        self.compute = compute if compute is not None else getattr(
            session, "compute", None)
        self.manager = manager if manager is not None else getattr(
            session, "manager", None)
        self.data_service = data_service if data_service is not None \
            else getattr(session, "data_service", None)
        if self.compute is None:
            raise ValueError("PilotSupervisor needs a session or compute=")
        self.interval_s = max(0.005, float(interval_s))
        self.suspect_phi = float(suspect_phi)
        self.dead_phi = float(dead_phi)
        self.max_respawns = int(max_respawns)
        self.auto_respawn = auto_respawn
        self.repair_interval_s = repair_interval_s
        self.backoff = backoff
        self.detector = FailureDetector(min_interval_s=min_heartbeat_s)
        self.respawns: List[RespawnEvent] = []
        self.events: List[dict] = []
        self._quarantined: set = set()
        self._handled: set = set()      # dead pilots already replaced
        self._forgotten: set = set()    # deliberately released pilots
        self._phi: Dict[str, float] = {}
        self._hb_age: Dict[str, float] = {}
        self._respawn_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._started = False

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "PilotSupervisor":
        if self._started:
            return self
        self._started = True
        if self.data_service is not None and hasattr(self.data_service,
                                                     "start_repair"):
            self.data_service.start_repair(interval_s=self.repair_interval_s)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="pilot-supervisor")
        self._thread.start()
        return self

    def close(self, timeout: float = 10.0) -> None:
        """Stop monitoring (joins the thread, so any in-flight respawn
        completes or aborts before this returns) and stop the repair
        worker.  Idempotent."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
        if self.data_service is not None and hasattr(self.data_service,
                                                     "stop_repair"):
            self.data_service.stop_repair()

    def forget(self, pilot_id: str) -> None:
        """Stop supervising a pilot (called before a deliberate release,
        so a mid-teardown CANCELED state is not mistaken for death)."""
        self._forgotten.add(pilot_id)
        self._readmit(pilot_id)
        self.detector.forget(pilot_id)

    # -- quarantine plumbing ---------------------------------------------
    def _quarantine(self, pid: str, why: str) -> None:
        if pid in self._quarantined:
            return
        self._quarantined.add(pid)
        policy = getattr(self.manager, "policy", None)
        if policy is not None:
            policy.quarantine(pid)
        ds = self.data_service
        if ds is not None and hasattr(ds, "avoid_pilot"):
            ds.avoid_pilot(pid)
        self.events.append({"op": "quarantine", "pilot": pid, "why": why,
                            "t": time.time()})

    def _readmit(self, pid: str) -> None:
        if pid not in self._quarantined:
            return
        self._quarantined.discard(pid)
        policy = getattr(self.manager, "policy", None)
        if policy is not None:
            policy.readmit(pid)
        ds = self.data_service
        if ds is not None and hasattr(ds, "readmit_pilot"):
            ds.readmit_pilot(pid)
        self.events.append({"op": "readmit", "pilot": pid,
                            "t": time.time()})

    @property
    def quarantined(self) -> frozenset:
        return frozenset(self._quarantined)

    @property
    def handled(self) -> frozenset:
        """Dead pilots this supervisor has already replaced (or given up
        on): the autoscaler must never pick one as a scale-in victim —
        respawn and scale-out share the provision path, not the corpse."""
        return frozenset(self._handled)

    # -- the monitor loop ------------------------------------------------
    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self._tick()
            except Exception:   # noqa: BLE001 - monitor must survive races
                pass

    def _tick(self) -> None:
        from repro.core.backends.base import get_backend
        now = time.monotonic()
        for pilot in list(self.compute.pilots.values()):
            pid = pilot.id
            if pid in self._forgotten or pid in self._handled:
                continue
            try:
                h = get_backend(pilot.desc.backend).health(pilot)
            except Exception:   # noqa: BLE001 - unhealthy adaptor == dead
                h = {"alive": False, "busy": False,
                     "last_heartbeat": 0.0}
            self.detector.observe(pid, float(h.get("last_heartbeat", 0.0)),
                                  now)
            phi = self.detector.phi(pid, now)
            self._phi[pid] = phi
            self._hb_age[pid] = float(h.get("heartbeat_age_s", 0.0))
            if not h.get("alive", False):
                self._on_dead(pilot, f"state:{h.get('state')}")
            elif phi >= self.dead_phi and not h.get("busy", False):
                self._on_dead(pilot, f"phi:{phi:.1f}")
            elif phi >= self.suspect_phi:
                self._quarantine(pid, f"phi:{phi:.1f}")
            else:
                self._readmit(pid)      # beats resumed: suspicion lifts

    def _on_dead(self, pilot: PilotCompute, reason: str) -> None:
        # quarantine FIRST: between confirmation and replacement no task
        # may late-bind onto the corpse
        self._quarantine(pilot.id, reason)
        if not self.auto_respawn:
            return
        if len(self.respawns) >= self.max_respawns:
            self.events.append({"op": "respawn-budget-exhausted",
                                "pilot": pilot.id, "t": time.time()})
            self._handled.add(pilot.id)
            return
        self.replace_pilot(pilot, reason=reason)

    # -- respawn ---------------------------------------------------------
    def replace_pilot(self, dead: PilotCompute,
                      desc=None, reason: str = "manual"
                      ) -> Optional[PilotCompute]:
        """Re-provision a replacement for `dead` from its own description
        (deregistering the corpse from the data service and the fleet
        first, so its replicas leave the registry before the new pilot
        joins).  Returns the new pilot, or None when the session closed
        under us — the one caller-visible race ``session.close()`` during
        an in-flight respawn can produce, by design."""
        with self._respawn_lock:
            if dead.id in self._handled:
                return None
            self._handled.add(dead.id)
            t0 = time.monotonic()
            new: Optional[PilotCompute] = None
            try:
                if self.session is not None:
                    new = self.session.respawn_pilot(dead)
                else:
                    ds = self.data_service
                    if ds is not None:
                        ds.unregister_pilot(dead.id)
                    try:
                        self.compute.release(dead)
                    except Exception:   # noqa: BLE001 - corpse teardown
                        pass
                    new = self.compute.submit_pilot(desc or dead.desc)
                    if (ds is not None
                            and getattr(new, "tier_manager", None)
                            is not None):
                        ds.register_pilot(new)
            except RuntimeError:
                new = None              # session closed mid-respawn
            finally:
                # the dead id leaves quarantine either way: the registry
                # must not accumulate ids of pilots that no longer exist
                self._readmit(dead.id)
                self.detector.forget(dead.id)
                ev = RespawnEvent(
                    old_pilot=dead.id,
                    new_pilot=new.id if new is not None else "",
                    reason=reason, downtime_s=time.monotonic() - t0,
                    t=time.time())
                self.respawns.append(ev)
                self.events.append({"op": "respawn", "old": ev.old_pilot,
                                    "new": ev.new_pilot, "why": reason,
                                    "t": ev.t})
        return new

    # -- telemetry -------------------------------------------------------
    def stats(self) -> dict:
        """Live supervision view: per-pilot heartbeat age + suspicion,
        the quarantine set, respawn history, and the data service's
        repair-queue depth / per-partition replication levels."""
        pilots = {}
        for pilot in list(self.compute.pilots.values()):
            pid = pilot.id
            pilots[pid] = {
                "state": getattr(pilot.state, "value", str(pilot.state)),
                "heartbeat_age_s": round(self._hb_age.get(pid, 0.0), 4),
                "phi": round(self._phi.get(pid, 0.0), 2),
                "quarantined": pid in self._quarantined,
            }
        out = {
            "pilots": pilots,
            "quarantined": sorted(self._quarantined),
            "respawns": [dataclasses.asdict(ev) for ev in self.respawns],
        }
        ds = self.data_service
        if ds is not None and hasattr(ds, "repair_queue_depth"):
            out["repair_queue_depth"] = ds.repair_queue_depth
            out["replication"] = ds.replication_stats()
        return out

    def __repr__(self) -> str:
        return (f"PilotSupervisor(pilots={len(self.compute.pilots)}, "
                f"quarantined={len(self._quarantined)}, "
                f"respawns={len(self.respawns)}, "
                f"{'running' if self._started and not self._stop.is_set() else 'stopped'})")
