"""TierManager: a capacity-aware memory hierarchy over Pilot-Data tiers.

The paper's central extension is Pilot-Data *Memory*: memory retained for a
set of tasks so iterative analytics never re-stage inputs (§3.3, the 212x
KMeans effect; the two-level-storage follow-up arXiv:1508.01847 gets the
same win from a managed burst-buffer tier). The flat backends in
repro.core.memory give the tiers themselves; this module adds the
management the paper assigns to Pilot-Data:

  * per-tier capacity budgets (bytes) — HBM and host RAM are finite;
  * pluggable eviction that *demotes* a partition to the next-colder tier
    (device -> host -> object/file -> checkpoint) instead of dropping it,
    so data is never lost to pressure.  With a checkpoint tier attached
    (the durable manifest-backed store of repro.core.memory) the hierarchy
    bottoms out on disk: pressure beyond the volatile budgets spills the
    coldest partitions to persistent storage and reads restore them
    lazily through the same copy-first/delete-last protocol, with heat
    promotion pulling hot restorees back up.  Policies: plain LRU
    (default, recency only)
    and GDSF (Greedy-Dual-Size-Frequency: priority = frequency x
    cost-of-restage / size, so a small hot partition outlives a large cold
    one even when the cold one was touched more recently);
  * eviction hysteresis: freshly demoted partitions sit out promotion (and
    freshly promoted ones are deprioritized as victims) for a configurable
    number of clock ticks, bounding demote/promote ping-pong under
    adversarial alternating access patterns;
  * access-heat tracking with automatic promotion of hot partitions
    toward the device tier (the Spark `persist()` analogue);
  * `pin`/`unpin` so a working set can be exempted from eviction;
  * an async staging pipeline (thread-pool stager returning futures) so
    stage-in/promotion overlaps with Compute-Unit execution.

Hot-path accounting is amortized: reads never take the manager-wide
metadata lock.  Residency lookup is a plain (GIL-atomic) dict read whose
staleness is tolerated by the copy-first/delete-last move protocol, and
heat/recency updates land in a sharded access ledger (one small lock per
shard, touched by at most a handful of readers each) that is folded into
the authoritative entries in batches — on shard overflow, when a key has
accumulated enough heat to matter for promotion, and always right before
an eviction decision, so LRU/GDSF victim selection still sees exact
recency and frequency.

A partition (key) is resident in exactly one managed tier at a time.
Moves — explicit stages *and* pressure demotions — copy to the destination
*before* deleting the source and flip the residency metadata in between,
so concurrent readers observe either-tier-consistent data and never a
hole.  The copy itself always runs outside the metadata lock (demotion
victims are fenced with the `_moving` marker while their bytes drain to
the colder tier), so a throttled cold tier never serializes concurrent
readers or stagers during reservation.

Zero-copy plane (PR 8): backend reads hand out read-only *views*
(mmap'd files, aliasing host views, dlpack device views — see
repro.core.buf), so a move's get+put pipes a view straight into the
destination encoder and the only memcpy in a demotion is the cold
tier's own write.  Deleting the source after the flip only drops the
store's reference: a reader's live view pins the backing bytes (numpy
base / mmap'd inode / dlpack capsule), so demotion and eviction can
never mutate data under a reader.  `get_buf` returns the same view
wrapped with provenance.

Multi-pilot note: one TierManager manages ONE pilot's tiers.  Cross-pilot
replication and coherence live a layer up in
repro.core.pilotdata.PilotDataService, which owns the mapping from
partition keys to the set of per-pilot managers holding a replica.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.buf import Buf, zero_copy_enabled
from repro.core.memory import (DEFAULT_TIER_BANDWIDTH, DURABLE_TIERS,
                               StorageBackend, TIERS)


class CapacityError(RuntimeError):
    """A tier budget cannot be satisfied (value too large or all pinned)."""


@dataclasses.dataclass
class _Entry:
    key: str
    tier: str
    nbytes: int
    pinned: bool = False
    heat: int = 0               # accesses since the last promotion decision
    freq: int = 0               # lifetime accesses (GDSF frequency term)
    last_access: int = 0
    no_promote_until: int = 0   # hysteresis stamp set on demotion
    no_demote_until: int = 0    # hysteresis stamp set on promotion


# -- eviction policies ---------------------------------------------------
class EvictionPolicy:
    """Chooses the victim among evictable entries of an over-budget tier.

    `candidates` is never empty, already filtered to unpinned, not-in-
    flight, not-excluded entries of `tier`.  Called with the manager's
    metadata lock held, so implementations must not call back into
    locking TierManager methods other than `_restage_cost_entry`.
    """

    name = "policy"

    def select_victim(self, tier: str, candidates: Sequence[_Entry],
                      manager: "TierManager") -> _Entry:
        raise NotImplementedError

    def on_evict(self, tier: str, entry: _Entry,
                 manager: "TierManager") -> None:
        """Hook invoked just before `entry` is demoted out of `tier`."""


class LRUPolicy(EvictionPolicy):
    """Pure recency (the PR 1 behavior; default)."""

    name = "lru"

    def select_victim(self, tier, candidates, manager):
        return min(candidates, key=lambda e: e.last_access)


class GDSFPolicy(EvictionPolicy):
    """Greedy-Dual-Size-Frequency with cost-of-restage weighting.

    priority(e) = L(tier at access time) + (1 + freq(e)) * restage_cost(e)
                  / size(e)

    restage_cost is the estimated seconds to bring the partition back
    (read from the next-colder tier + write back into this one), derived
    from the TierProfile bandwidths/latencies, so evicting data that is
    expensive to re-stage requires proportionally more pressure.  L is the
    classic GDSF aging term: each eviction inflates it to the evicted
    priority, and an entry's priority is *frozen with the L current at its
    last access* (recomputed only when its freq/tier changes), so a once-
    hot long-idle entry keeps its stale small-L priority while freshly
    accessed entries earn the inflated one — long-idle data eventually
    becomes evictable instead of squatting on its lifetime frequency.
    """

    name = "gdsf"

    def __init__(self):
        self._L: Dict[str, float] = {}
        # key -> (freq, tier, H): H computed with L at that access state
        self._h: Dict[str, tuple] = {}

    def priority(self, entry: _Entry, manager: "TierManager") -> float:
        cached = self._h.get(entry.key)
        if (cached is not None and cached[0] == entry.freq
                and cached[1] == entry.tier):
            return cached[2]
        cost = manager._restage_cost_entry(entry)
        h = (self._L.get(entry.tier, 0.0)
             + (1.0 + entry.freq) * cost / max(entry.nbytes, 1))
        self._h[entry.key] = (entry.freq, entry.tier, h)
        return h

    def select_victim(self, tier, candidates, manager):
        return min(candidates,
                   key=lambda e: (self.priority(e, manager), e.last_access))

    def on_evict(self, tier, entry, manager):
        self._L[tier] = self.priority(entry, manager)
        self._h.pop(entry.key, None)
        if len(self._h) > 2 * len(manager._entries):
            self._h = {k: v for k, v in self._h.items()
                       if k in manager._entries}


def make_policy(policy: Union[str, EvictionPolicy]) -> EvictionPolicy:
    if isinstance(policy, EvictionPolicy):
        return policy
    if policy == "lru":
        return LRUPolicy()
    if policy == "gdsf":
        return GDSFPolicy()
    raise ValueError(f"unknown eviction policy {policy!r} "
                     "(expected 'lru', 'gdsf', or an EvictionPolicy)")


# -- amortized access accounting ----------------------------------------
class _AccessLedger:
    """Sharded pending-access counters; the lock-contention absorber.

    Readers record (count, last-clock) per key under a shard-local lock and
    the shards are drained into the authoritative entries in batches.  The
    global metadata lock is never taken on the record path; drain() is only
    called by holders of the metadata lock (lock order: meta -> shard)."""

    def __init__(self, nshards: int = 8, flush_every: int = 64,
                 key_trigger: int = 0):
        self.nshards = max(1, nshards)
        self.flush_every = max(1, flush_every)
        self.key_trigger = key_trigger      # promote_threshold fast path
        self._shards: List[Dict[str, List[int]]] = [
            {} for _ in range(self.nshards)]
        self._locks = [threading.Lock() for _ in range(self.nshards)]
        self._pending = [0] * self.nshards

    def record(self, key: str, clock: int) -> Tuple[bool, int]:
        """Note one access; returns (flush-now?, key's pending count)."""
        i = hash(key) % self.nshards
        with self._locks[i]:
            ent = self._shards[i].get(key)
            if ent is None:
                ent = self._shards[i][key] = [0, 0]
            ent[0] += 1
            if clock > ent[1]:
                ent[1] = clock
            self._pending[i] += 1
            flush = (self._pending[i] >= self.flush_every
                     or (self.key_trigger > 0 and ent[0] >= self.key_trigger))
            return flush, ent[0]

    def drain(self) -> Dict[str, Tuple[int, int]]:
        out: Dict[str, Tuple[int, int]] = {}
        for i in range(self.nshards):
            with self._locks[i]:
                if not self._shards[i]:
                    continue
                for k, (cnt, last) in self._shards[i].items():
                    prev = out.get(k)
                    if prev is None:
                        out[k] = (cnt, last)
                    else:
                        out[k] = (prev[0] + cnt, max(prev[1], last))
                self._shards[i].clear()
                self._pending[i] = 0
        return out


class TierManager:
    """Managed placement of named partitions across storage tiers.

    backends — tier name -> StorageBackend (any subset of TIERS).
    budgets  — tier name -> capacity in bytes; missing/None = unbounded.
    promote_threshold — accesses after which a partition is asynchronously
        promoted one tier hotter (0 disables auto-promotion).
    policy — eviction policy: "lru" (default), "gdsf", or an
        EvictionPolicy instance.
    hysteresis — clock ticks a demoted partition sits out re-promotion
        (and a promoted one is deprioritized as a victim); 0 disables.
    """

    def __init__(self, backends: Dict[str, StorageBackend],
                 budgets: Optional[Dict[str, Optional[int]]] = None,
                 *, promote_threshold: int = 4, max_workers: int = 2,
                 policy: Union[str, EvictionPolicy] = "lru",
                 hysteresis: int = 0, ledger_shards: int = 8,
                 ledger_flush_every: int = 64):
        unknown = set(backends) - set(TIERS)
        if unknown:
            raise ValueError(f"unknown tiers {sorted(unknown)}")
        self.backends = dict(backends)
        # cold -> hot, restricted to the tiers that actually have backends
        self.order: List[str] = [t for t in TIERS if t in backends]
        self.budgets: Dict[str, Optional[int]] = {
            t: (budgets or {}).get(t) for t in self.order}
        self.promote_threshold = promote_threshold
        self.policy = make_policy(policy)
        self.hysteresis = int(hysteresis)
        self._entries: Dict[str, _Entry] = {}
        self._usage: Dict[str, int] = {t: 0 for t in self.order}
        self._peak: Dict[str, int] = {t: 0 for t in self.order}
        self._tick = itertools.count(1)   # GIL-atomic monotonic clock
        self._latest_tick = 0
        self._ledger = _AccessLedger(ledger_shards, ledger_flush_every,
                                     key_trigger=promote_threshold)
        self._meta = threading.RLock()
        self._moving: set = set()      # keys with a copy in flight
        self._inflight: Dict[tuple, Future] = {}
        self._closed = False
        self._lost = False             # node death: refuse new placements
        self._executor = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="tier-stager")
        self.events: List[dict] = []   # telemetry: evict/demote/promote/stage
        self.counters: Dict[str, int] = {
            "demotions": 0, "promotions": 0, "bytes_demoted": 0,
            "bytes_promoted": 0, "stage_refused": 0}

    # -- clock ----------------------------------------------------------
    def _tick_next(self) -> int:
        t = next(self._tick)
        self._latest_tick = t   # benign race: only needs to be monotone-ish
        return t

    def _now(self) -> int:
        return self._latest_tick

    # -- introspection --------------------------------------------------
    def budget(self, tier: str) -> Optional[int]:
        return self.budgets.get(tier)

    def usage(self, tier: str) -> int:
        with self._meta:
            return self._usage.get(tier, 0)

    def peak_usage(self, tier: str) -> int:
        with self._meta:
            return self._peak.get(tier, 0)

    def tier_of(self, key: str) -> Optional[str]:
        e = self._entries.get(key)
        return e.tier if e else None

    def entry_nbytes(self, key: str) -> int:
        with self._meta:
            return self._entries[key].nbytes

    def resident_keys(self, tier: str) -> List[str]:
        with self._meta:
            return [k for k, e in self._entries.items() if e.tier == tier]

    def stats(self) -> Dict[str, dict]:
        with self._meta:
            self._apply_ledger_locked(allow_promote=False)
            out = {}
            for t in self.order:
                ent = [e for e in self._entries.values() if e.tier == t]
                out[t] = {"usage": self._usage[t], "peak": self._peak[t],
                          "budget": self.budgets[t], "entries": len(ent),
                          "pinned": sum(e.pinned for e in ent)}
            return out

    def event_summary(self) -> Dict[str, int]:
        """Cumulative movement counters (for benchmarks/CI artifacts)."""
        with self._meta:
            return dict(self.counters)

    def restage_cost(self, key: str) -> float:
        """Estimated seconds to re-stage `key` from the next-colder tier."""
        with self._meta:
            return self._restage_cost_entry(self._entries[key])

    def _transfer_cost(self, src: str, dst: str, nbytes: int) -> float:
        """Seconds to read `nbytes` from `src` and write them into `dst`
        (profile bandwidths, nominal per-tier defaults when unthrottled)."""
        rp = self.backends[src].profile
        read_bw = rp.read_bw or DEFAULT_TIER_BANDWIDTH.get(src, 1e9)
        wp = self.backends[dst].profile if dst in self.backends else rp
        write_bw = wp.write_bw or DEFAULT_TIER_BANDWIDTH.get(dst, 1e9)
        return (rp.latency + nbytes / read_bw
                + wp.latency + nbytes / write_bw)

    def _restage_cost_entry(self, e: _Entry) -> float:
        colder = self._colder(e.tier) or e.tier
        return self._transfer_cost(colder, e.tier, e.nbytes)

    def promote_cost(self, key: str, tier: str) -> float:
        """Estimated seconds to stage `key` from where it currently resides
        into `tier` — the lazy-restore cost a prefetch planner should
        budget for.  Unlike `restage_cost` (the hypothetical cost of
        bringing the key back after one more demotion), this bills the
        bandwidth of the key's ACTUAL tier, so a checkpoint-resident
        partition is priced at the persistent store's bandwidth, not the
        host tier's."""
        with self._meta:
            e = self._entries[key]
            if e.tier == tier:
                return 0.0
            return self._transfer_cost(e.tier, tier, e.nbytes)

    # -- internal helpers (meta lock held) ------------------------------
    def _hotter(self, tier: str) -> Optional[str]:
        i = self.order.index(tier)
        return self.order[i + 1] if i + 1 < len(self.order) else None

    def _colder(self, tier: str) -> Optional[str]:
        i = self.order.index(tier)
        return self.order[i - 1] if i > 0 else None

    def _touch(self, e: _Entry) -> None:
        e.last_access = self._tick_next()
        e.heat += 1
        e.freq += 1

    def _charge(self, tier: str, nbytes: int) -> None:
        self._usage[tier] += nbytes
        if self._usage[tier] > self._peak[tier]:
            self._peak[tier] = self._usage[tier]

    def _apply_ledger_locked(self, allow_promote: bool = True) -> List[tuple]:
        """Fold pending ledger records into the entries; return promotion
        targets (key, tier) to schedule once the lock is released."""
        recs = self._ledger.drain()
        promote: List[tuple] = []
        if not recs:
            return promote
        now = self._now()
        for key, (cnt, last) in recs.items():
            e = self._entries.get(key)
            if e is None:
                continue
            e.heat += cnt
            e.freq += cnt
            if last > e.last_access:
                e.last_access = last
            if (allow_promote and self.promote_threshold
                    and e.heat >= self.promote_threshold):
                # the decision consumes the heat either way: blocked keys
                # (hysteresis, hottest tier, oversized) re-earn it instead
                # of re-triggering a flush on every subsequent read
                e.heat = 0
                if now < e.no_promote_until:
                    continue
                hot = self._hotter(e.tier)
                budget = self.budgets.get(hot) if hot else None
                if hot is not None and (budget is None
                                        or e.nbytes <= budget):
                    promote.append((key, hot))
        return promote

    def _flush_accounting(self) -> None:
        with self._meta:
            promote = self._apply_ledger_locked()
        for key, tier in promote:
            self.stage_async(key, tier)

    def _fits_locked(self, tier: str, need: int) -> bool:
        """Whether charging `need` bytes keeps `tier` within budget (meta
        lock held). Raises CapacityError when `need` can never fit."""
        budget = self.budgets.get(tier)
        if budget is None or need <= 0:
            return True
        if need > budget:
            raise CapacityError(
                f"{need} bytes exceed the whole {tier!r} budget ({budget})")
        return self._usage[tier] + need <= budget

    def _evict_one(self, tier: str, exclude: frozenset,
                   deadline: float) -> None:
        """Demote one policy-chosen victim out of `tier`, with the data copy
        performed OUTSIDE the metadata lock (the same copy-first/delete-last
        protocol as `stage`), so a slow write into a throttled colder tier
        no longer serializes concurrent readers and stagers during
        reservation.  Returns after one demotion landed — or after a short
        wait when victims are mid-move and may free room on their own — and
        the caller re-tests the budget; raises CapacityError when the tier
        holds nothing evictable at all."""
        with self._meta:
            # eviction decisions must see exact recency/frequency
            self._apply_ledger_locked(allow_promote=False)
            victims = [e for e in self._entries.values()
                       if e.tier == tier and not e.pinned
                       and e.key not in exclude
                       and e.key not in self._moving]
            if not victims:
                moving_here = any(e.tier == tier and e.key in self._moving
                                  for e in self._entries.values())
                if not moving_here:
                    raise CapacityError(
                        f"tier {tier!r} over budget and nothing evictable "
                        f"(usage={self._usage[tier]}, "
                        f"budget={self.budgets.get(tier)})")
                victim = None
            else:
                if self.hysteresis:
                    # prefer victims past their promotion hold-down;
                    # capacity is a hard constraint, so fall back to all
                    now = self._now()
                    settled = [e for e in victims
                               if e.no_demote_until <= now]
                    victims = settled or victims
                victim = self.policy.select_victim(tier, victims, self)
                dst = self._colder(tier)
                if dst is None:
                    raise CapacityError(
                        f"cannot evict {victim.key!r}: {tier!r} is the "
                        "coldest tier")
                self.policy.on_evict(tier, victim, self)
                self._moving.add(victim.key)
                key, nbytes = victim.key, victim.nbytes
        if victim is None:
            time.sleep(0.001)   # an in-flight move may free the room
            return
        charged = False
        try:
            # reserve room in the colder tier (may recurse further down);
            # a tier whose WHOLE budget is smaller than the victim is
            # skipped over — the victim falls through toward the durable
            # floor instead of wedging the demotion chain (a host tier
            # sized below the partition must not block the spill to disk)
            while True:
                with self._meta:
                    try:
                        fits = self._fits_locked(dst, nbytes)
                    except CapacityError:
                        nxt = self._colder(dst)
                        if nxt is None:
                            raise
                        dst = nxt
                        continue
                    if fits:
                        self._charge(dst, nbytes)
                        charged = True
                        break
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"eviction contention on tier {tier!r}")
                self._evict_one(dst, exclude | {key}, deadline)
            # the copy itself: readers and stagers proceed meanwhile
            val = self.backends[tier].get(key)
            self.backends[dst].put(key, val)
        except (KeyError, FileNotFoundError):
            # victim deleted mid-demotion: its space is already freed
            with self._meta:
                if charged:
                    self._usage[dst] -= nbytes
                self._moving.discard(key)
            return
        except BaseException:
            with self._meta:
                if charged:
                    self._usage[dst] -= nbytes
                self._moving.discard(key)
            raise
        with self._meta:
            e = self._entries.get(key)
            if e is None:       # deleted mid-move: drop the staged copy
                self._usage[dst] -= nbytes
                self.backends[dst].delete(key)
                self._moving.discard(key)
                return
            e.tier = dst
            e.heat = 0          # demoted data must re-earn promotion
            if self.hysteresis:
                e.no_promote_until = self._now() + self.hysteresis
            self._usage[tier] -= nbytes
            self.backends[tier].delete(key)
            self._moving.discard(key)
            self.counters["demotions"] += 1
            self.counters["bytes_demoted"] += nbytes
            self.events.append({"op": "demote", "key": key, "from": tier,
                                "to": dst, "bytes": nbytes})

    # -- placement ------------------------------------------------------
    def put(self, key: str, value, tier: str, pinned: bool = False) -> None:
        """Store `value` in `tier`, evicting (demoting) data to fit.

        On CapacityError nothing has changed: a pre-existing copy of the
        key (any tier) is still resident and correctly accounted.
        """
        if tier not in self.backends:
            raise KeyError(f"no backend for tier {tier!r}")
        if self._lost:
            raise CapacityError(
                "tier manager lost its node (lose_volatile); refusing "
                "new placements")
        arr = value if hasattr(value, "nbytes") else np.asarray(value)
        nbytes = int(arr.nbytes)
        deadline = time.monotonic() + 30.0
        while True:
            evict = False
            with self._meta:
                if key not in self._moving:
                    old = self._entries.get(key)
                    freed = old.nbytes if (old is not None
                                           and old.tier == tier) else 0
                    # reserve before touching the old copy, so a
                    # CapacityError here leaves it intact (the "never lost
                    # to pressure" guarantee)
                    if self._fits_locked(tier, nbytes - freed):
                        self._usage[tier] -= freed
                        self._charge(tier, nbytes)
                        try:
                            self.backends[tier].put(key, arr)
                        except Exception:
                            self._usage[tier] += freed - nbytes
                            raise
                        if old is not None and old.tier != tier:
                            self._usage[old.tier] -= old.nbytes
                            self.backends[old.tier].delete(key)
                        self._entries[key] = _Entry(
                            key, tier, nbytes, pinned=pinned,
                            last_access=self._tick_next())
                        return
                    evict = True
            if time.monotonic() > deadline:
                raise RuntimeError(f"staging contention on {key!r}")
            if evict:
                self._evict_one(tier, frozenset({key}), deadline)
            else:
                time.sleep(0.001)   # key mid-move; wait for the stager

    def delete(self, key: str) -> None:
        with self._meta:
            e = self._entries.pop(key, None)
            if e is None:
                return
            self._usage[e.tier] -= e.nbytes
            self.backends[e.tier].delete(key)
            # purge the untracked durable copies promotions leave behind,
            # so a deleted key can never be resurrected from the store
            for t in DURABLE_TIERS:
                if t != e.tier and t in self.backends:
                    self.backends[t].delete(key)

    def lose_volatile(self) -> List[str]:
        """Simulate node loss: drop every entry resident in a volatile
        tier (everything but the durable checkpoint store) — metadata,
        accounting, and backend bytes.  Checkpoint-resident entries
        survive and stay readable; the keys lost are returned so callers
        (fault harnesses, the PilotDataService) can account for them."""
        lost: List[str] = []
        with self._meta:
            self._lost = True    # in-flight replications must not revive
            #                      the dead node's tiers
            self._apply_ledger_locked(allow_promote=False)
            for key, e in list(self._entries.items()):
                if e.tier in DURABLE_TIERS:
                    continue
                self._usage[e.tier] -= e.nbytes
                self.backends[e.tier].delete(key)
                del self._entries[key]
                lost.append(key)
            self.events.append({"op": "lose-volatile", "keys": len(lost)})
        return lost

    def adopt(self, key: str, tier: str, nbytes: Optional[int] = None,
              pinned: bool = False) -> None:
        """Register data already sitting in a backend (e.g. a pre-existing
        DataUnit) so it participates in budgets/eviction/heat."""
        if self._lost:
            raise CapacityError(
                "tier manager lost its node (lose_volatile); refusing "
                "new placements")
        if nbytes is None:
            nbytes = self.backends[tier].nbytes(key)
        deadline = time.monotonic() + 30.0
        while True:
            with self._meta:
                if key in self._entries:
                    return
                if self._fits_locked(tier, int(nbytes)):
                    self._charge(tier, int(nbytes))
                    self._entries[key] = _Entry(
                        key, tier, int(nbytes), pinned=pinned,
                        last_access=self._tick_next())
                    return
            if time.monotonic() > deadline:
                raise RuntimeError(f"adoption contention on {key!r}")
            self._evict_one(tier, frozenset({key}), deadline)

    # -- access ---------------------------------------------------------
    def get(self, key: str) -> np.ndarray:
        """Read a partition from wherever it currently resides.

        Lock-free on the hot path: residency is a GIL-atomic dict read and
        access accounting goes through the sharded ledger.  Tolerates
        concurrent staging: a move copies to the destination, flips
        residency, then deletes the source, so on a miss we re-read the
        (updated) residency and retry.
        """
        for _ in range(8):
            e = self._entries.get(key)      # snapshot; staleness tolerated
            tier = e.tier if e else None
            if tier is None:
                break
            try:
                val = self.backends[tier].get(key)
            except (KeyError, FileNotFoundError):
                continue    # raced with a move; residency will have flipped
            self._after_read(key)
            return val
        # last resort: scan every backend (covers unmanaged stragglers)
        for tier in reversed(self.order):
            be = self.backends[tier]
            try:
                if be.exists(key):
                    val = be.get(key)
                    self._after_read(key)
                    return val
            except (KeyError, FileNotFoundError):
                continue
        raise KeyError(key)

    def get_buf(self, key: str) -> Buf:
        """Like `get`, but wraps the read-only view in a `Buf` carrying
        provenance (the tier the bytes were served from) and ownership.
        Since the backends hand out views under zero-copy and owned
        copies in copy mode, no extra bytes move here."""
        e = self._entries.get(key)      # snapshot; staleness tolerated
        tier = e.tier if e else None
        val = self.get(key)
        if tier is None:
            tier = self.tier_of(key)
        return Buf(val, source=tier or "?",
                   owned=not zero_copy_enabled())

    def get_device(self, key: str):
        """Device-resident handle if HBM holds the key; else staged read."""
        import jax
        e = self._entries.get(key)          # lock-free residency snapshot
        tier = e.tier if e else None
        be = self.backends.get("device")
        if tier == "device" and be is not None and hasattr(be, "get_device"):
            try:
                arr = be.get_device(key)
                self._after_read(key)
                return arr
            except KeyError:
                pass
            except FileNotFoundError:
                pass
        return jax.device_put(np.asarray(self.get(key)))

    def _after_read(self, key: str) -> None:
        flush, pending = self._ledger.record(key, self._tick_next())
        if not flush and self.promote_threshold:
            # non-promoting drains (eviction, stats) may have consumed
            # part of this key's window while its accumulated heat kept
            # growing; a lock-free peek over drained heat + pending window
            # keeps the PR 1 guarantee that the threshold-th read triggers
            # the promotion decision
            e = self._entries.get(key)
            flush = (e is not None
                     and e.heat + pending >= self.promote_threshold)
        if flush:
            self._flush_accounting()

    # -- pinning --------------------------------------------------------
    def pin(self, keys: Iterable[str] | str) -> None:
        self._set_pinned(keys, True)

    def unpin(self, keys: Iterable[str] | str) -> None:
        self._set_pinned(keys, False)

    def _set_pinned(self, keys, flag: bool) -> None:
        if isinstance(keys, str):
            keys = (keys,)
        with self._meta:
            for k in keys:
                e = self._entries.get(k)
                if e is not None:
                    e.pinned = flag

    # -- staging --------------------------------------------------------
    def stage(self, key: str, tier: str, keep_source: bool = False) -> str:
        """Synchronously move `key` to `tier` (promotion or demotion).

        With keep_source=True the source copy is left behind (untracked,
        cold-tier cache); residency metadata moves to the destination.
        Promotion out of a DURABLE tier always keeps the source copy —
        staging a partition up from the checkpoint store must not delete
        the only copy that survives node loss (data staged in from Lustre
        is not removed from Lustre); a later demotion simply overwrites
        it.  Returns the tier the key resides in afterwards.

        The copy itself runs *outside* the metadata lock (so staging
        overlaps concurrent reads/compute); the lock is taken only to
        reserve destination capacity and to flip residency. Concurrent
        stages of the same key serialize on the `_moving` marker.
        """
        if tier not in self.backends:
            raise KeyError(f"no backend for tier {tier!r}")
        if self._lost and tier not in DURABLE_TIERS:
            raise CapacityError(
                "tier manager lost its node (lose_volatile); refusing "
                "stages into volatile tiers")
        deadline = time.monotonic() + 30.0
        while True:
            evict = False
            reserved = False
            with self._meta:
                e = self._entries.get(key)
                if e is None:
                    raise KeyError(key)
                if key not in self._moving:
                    src = e.tier
                    if src == tier:
                        self._touch(e)
                        return tier
                    nbytes = e.nbytes
                    if self._fits_locked(tier, nbytes):
                        self._charge(tier, nbytes)
                        self._moving.add(key)
                        reserved = True
                    else:
                        evict = True
            if reserved:
                break
            if time.monotonic() > deadline:
                raise RuntimeError(f"staging contention on {key!r}")
            if evict:
                self._evict_one(tier, frozenset({key}), deadline)
            else:
                time.sleep(0.001)   # another mover has this key; wait it out
        try:
            val = self.backends[src].get(key)      # outside the lock:
            self.backends[tier].put(key, val)      # reads proceed meanwhile
        except Exception:
            with self._meta:
                self._usage[tier] -= nbytes
                self._moving.discard(key)
            raise
        with self._meta:
            e = self._entries.get(key)
            if e is None:
                # deleted mid-move: drop the staged copy + reservation
                self._usage[tier] -= nbytes
                self.backends[tier].delete(key)
                self._moving.discard(key)
                raise KeyError(key)
            e.tier = tier
            self._touch(e)
            self._usage[src] -= nbytes
            if not keep_source and src not in DURABLE_TIERS:
                self.backends[src].delete(key)
            self._moving.discard(key)
            hot = self.order.index(tier) > self.order.index(src)
            if self.hysteresis:
                if hot:
                    e.no_demote_until = self._now() + self.hysteresis
                else:
                    e.no_promote_until = self._now() + self.hysteresis
            op = "promote" if hot else "demote"
            self.counters["promotions" if hot else "demotions"] += 1
            self.counters["bytes_promoted" if hot
                          else "bytes_demoted"] += nbytes
            self.events.append({"op": op, "key": key, "from": src,
                                "to": tier, "bytes": nbytes})
        return tier

    def stage_async(self, key: str, tier: str,
                    keep_source: bool = False) -> Future:
        """Queue a move on the background stager; returns a future resolving
        to the tier the key ends up in (the current tier if the move was
        refused for capacity, or immediately after close())."""
        with self._meta:
            if self._closed:
                fut: Future = Future()
                fut.set_result(self.tier_of(key) or tier)
                return fut
            fut = self._inflight.get((key, tier))
            if fut is not None and not fut.done():
                return fut
            for k in [k for k, f in self._inflight.items() if f.done()]:
                del self._inflight[k]   # don't retain completed stages
            fut = self._executor.submit(
                self._stage_task, key, tier, keep_source)
            self._inflight[(key, tier)] = fut
            return fut

    def _stage_task(self, key: str, tier: str, keep_source: bool) -> str:
        try:
            return self.stage(key, tier, keep_source=keep_source)
        except CapacityError:
            with self._meta:
                self.counters["stage_refused"] += 1
                self.events.append({"op": "stage-refused", "key": key,
                                    "to": tier})
            return self.tier_of(key) or tier
        except KeyError:
            return tier   # key deleted while queued; nothing to do

    def prefetch(self, key: str, tier: str) -> Optional[Future]:
        """Async promotion toward `tier` if the key is currently colder."""
        with self._meta:
            e = self._entries.get(key)
            if e is None or tier not in self.backends:
                return None
            if self.order.index(e.tier) >= self.order.index(tier):
                return None
        return self.stage_async(key, tier)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Wait for every queued stage to finish (tests/benchmarks)."""
        with self._meta:
            futs = list(self._inflight.values())
        for f in futs:
            if f.cancelled():
                continue
            try:
                f.result(timeout)
            except CancelledError:
                continue
        self._flush_accounting()

    def close(self) -> None:
        """Deterministic shutdown: refuse new stages, cancel queued moves,
        wait for in-flight ones to land, and join the stager threads, so
        no tier-stager thread or half-applied move outlives the manager.
        Backends with a durability barrier (the checkpoint tier's `flush`)
        are flushed LAST — after every stager-driven demotion has landed —
        so all in-flight checkpoint writes are on disk and the manifest is
        fsync'd: a store reopened after close() is exactly consistent with
        this manager's final residency.  Idempotent; reads keep working
        afterwards."""
        with self._meta:
            if self._closed:
                return
            self._closed = True
        # queued-but-unstarted moves are cancelled (their capacity is only
        # reserved once they run, so nothing leaks); running moves complete
        # their copy-first/delete-last protocol before the join returns
        self._executor.shutdown(wait=True, cancel_futures=True)
        with self._meta:
            self._inflight.clear()
            self._apply_ledger_locked(allow_promote=False)
        for be in self.backends.values():
            flush = getattr(be, "flush", None)
            if flush is not None:
                flush()     # write barrier + fsync'd manifest

    def __repr__(self) -> str:
        parts = ", ".join(
            f"{t}={self._usage[t]}/{self.budgets[t] or 'inf'}"
            for t in self.order)
        return f"TierManager({parts}, policy={self.policy.name})"


def make_tier_manager(*, device_budget: Optional[int] = None,
                      host_budget: Optional[int] = None,
                      root: Optional[str] = None, mesh=None,
                      promote_threshold: int = 4,
                      policy: Union[str, EvictionPolicy] = "lru",
                      hysteresis: int = 0,
                      max_workers: int = 2,
                      checkpoint_root: Optional[str] = None,
                      checkpoint_budget: Optional[int] = None) -> TierManager:
    """Convenience: a host(+file)(+device) hierarchy with common budgets.

    Without `root` the coldest volatile tier is host RAM (no disk side
    effects); with `root` a file tier is added below it.  With
    `checkpoint_root` a durable checkpoint tier is added at the very
    bottom (shared per directory — several managers naming the same root
    get the same store instance), so pressure demotions beyond the
    volatile budgets spill to persistent storage instead of refusing.
    """
    from repro.core.memory import make_backend
    backends: Dict[str, StorageBackend] = {}
    if checkpoint_root is not None:
        backends["checkpoint"] = make_backend("checkpoint",
                                              root=checkpoint_root)
    if root is not None:
        backends["file"] = make_backend("file", root=root)
    backends["host"] = make_backend("host")
    backends["device"] = make_backend("device", mesh=mesh)
    budgets: Dict[str, Optional[int]] = {}
    if device_budget is not None:
        budgets["device"] = int(device_budget)
    if host_budget is not None:
        budgets["host"] = int(host_budget)
    if checkpoint_budget is not None:
        budgets["checkpoint"] = int(checkpoint_budget)
    return TierManager(backends, budgets, promote_threshold=promote_threshold,
                       policy=policy, hysteresis=hysteresis,
                       max_workers=max_workers)


def tier_manager_for_pilot(desc, mesh=None) -> Optional[TierManager]:
    """Per-pilot managed memory from a PilotComputeDescription resource ask
    (shared by the backend adaptors; None when no memory_gb was asked).

    The YARN-style `memory_gb` becomes the pilot's device-tier budget and
    `host_memory_gb` (optional) its host-tier budget: DUs placed — or
    replicated by the PilotDataService — into this manager are retained in
    the pilot's HBM share up to the ask and demoted through its own host
    tier beyond it, making each pilot a separate locality domain.

    `checkpoint_dir` adds the durable checkpoint tier beneath the volatile
    budgets (`checkpoint_gb` optionally bounds it; 0 = unbounded): the
    pilot spills its coldest partitions there under pressure instead of
    refusing, restores lazily on read, and — because the store is shared
    per directory — pilots naming the same dir form one persistent home
    the PilotDataService can recover replicas from after a pilot dies.

    Accepts the v2 composed description (reads its `memory`/`durability`
    blocks) or any object carrying the flat legacy fields."""
    mem = getattr(desc, "memory", None)
    if mem is None:
        mem = desc                      # flat legacy / duck-typed object
    dur = getattr(desc, "durability", None)
    if dur is None:
        dur = desc
    if not getattr(mem, "memory_gb", 0):
        return None
    ckpt_dir = getattr(dur, "checkpoint_dir", "") or None
    ckpt_gb = getattr(dur, "checkpoint_gb", 0.0)
    return make_tier_manager(
        device_budget=int(mem.memory_gb * 2 ** 30),
        host_budget=(int(mem.host_memory_gb * 2 ** 30)
                     if mem.host_memory_gb else None),
        mesh=mesh, policy=mem.eviction_policy,
        hysteresis=mem.hysteresis, max_workers=mem.stager_workers,
        checkpoint_root=ckpt_dir,
        checkpoint_budget=(int(ckpt_gb * 2 ** 30) if ckpt_gb else None))
