"""TierManager: a capacity-aware memory hierarchy over Pilot-Data tiers.

The paper's central extension is Pilot-Data *Memory*: memory retained for a
set of tasks so iterative analytics never re-stage inputs (§3.3, the 212x
KMeans effect; the two-level-storage follow-up arXiv:1508.01847 gets the
same win from a managed burst-buffer tier). The flat backends in
repro.core.memory give the tiers themselves; this module adds the
management the paper assigns to Pilot-Data:

  * per-tier capacity budgets (bytes) — HBM and host RAM are finite;
  * LRU eviction that *demotes* a partition to the next-colder tier
    (device -> host -> object/file) instead of dropping it, so data is
    never lost to pressure;
  * access-heat tracking with automatic promotion of hot partitions
    toward the device tier (the Spark `persist()` analogue);
  * `pin`/`unpin` so a working set can be exempted from eviction;
  * an async staging pipeline (thread-pool stager returning futures) so
    stage-in/promotion overlaps with Compute-Unit execution.

A partition (key) is resident in exactly one managed tier at a time.
Moves copy to the destination *before* deleting the source and flip the
residency metadata in between, so concurrent readers observe
either-tier-consistent data and never a hole.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.memory import StorageBackend, TIERS


class CapacityError(RuntimeError):
    """A tier budget cannot be satisfied (value too large or all pinned)."""


@dataclasses.dataclass
class _Entry:
    key: str
    tier: str
    nbytes: int
    pinned: bool = False
    heat: int = 0
    last_access: int = 0


class TierManager:
    """Managed placement of named partitions across storage tiers.

    backends — tier name -> StorageBackend (any subset of TIERS).
    budgets  — tier name -> capacity in bytes; missing/None = unbounded.
    promote_threshold — accesses after which a partition is asynchronously
        promoted one tier hotter (0 disables auto-promotion).
    """

    def __init__(self, backends: Dict[str, StorageBackend],
                 budgets: Optional[Dict[str, Optional[int]]] = None,
                 *, promote_threshold: int = 4, max_workers: int = 2):
        unknown = set(backends) - set(TIERS)
        if unknown:
            raise ValueError(f"unknown tiers {sorted(unknown)}")
        self.backends = dict(backends)
        # cold -> hot, restricted to the tiers that actually have backends
        self.order: List[str] = [t for t in TIERS if t in backends]
        self.budgets: Dict[str, Optional[int]] = {
            t: (budgets or {}).get(t) for t in self.order}
        self.promote_threshold = promote_threshold
        self._entries: Dict[str, _Entry] = {}
        self._usage: Dict[str, int] = {t: 0 for t in self.order}
        self._peak: Dict[str, int] = {t: 0 for t in self.order}
        self._clock = 0
        self._meta = threading.RLock()
        self._moving: set = set()      # keys with a copy in flight
        self._inflight: Dict[tuple, Future] = {}
        self._executor = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="tier-stager")
        self.events: List[dict] = []   # telemetry: evict/demote/promote/stage

    # -- introspection --------------------------------------------------
    def budget(self, tier: str) -> Optional[int]:
        return self.budgets.get(tier)

    def usage(self, tier: str) -> int:
        with self._meta:
            return self._usage.get(tier, 0)

    def peak_usage(self, tier: str) -> int:
        with self._meta:
            return self._peak.get(tier, 0)

    def tier_of(self, key: str) -> Optional[str]:
        with self._meta:
            e = self._entries.get(key)
            return e.tier if e else None

    def entry_nbytes(self, key: str) -> int:
        with self._meta:
            return self._entries[key].nbytes

    def resident_keys(self, tier: str) -> List[str]:
        with self._meta:
            return [k for k, e in self._entries.items() if e.tier == tier]

    def stats(self) -> Dict[str, dict]:
        with self._meta:
            out = {}
            for t in self.order:
                ent = [e for e in self._entries.values() if e.tier == t]
                out[t] = {"usage": self._usage[t], "peak": self._peak[t],
                          "budget": self.budgets[t], "entries": len(ent),
                          "pinned": sum(e.pinned for e in ent)}
            return out

    # -- internal helpers (meta lock held) ------------------------------
    def _hotter(self, tier: str) -> Optional[str]:
        i = self.order.index(tier)
        return self.order[i + 1] if i + 1 < len(self.order) else None

    def _colder(self, tier: str) -> Optional[str]:
        i = self.order.index(tier)
        return self.order[i - 1] if i > 0 else None

    def _touch(self, e: _Entry) -> None:
        self._clock += 1
        e.last_access = self._clock
        e.heat += 1

    def _charge(self, tier: str, nbytes: int) -> None:
        self._usage[tier] += nbytes
        if self._usage[tier] > self._peak[tier]:
            self._peak[tier] = self._usage[tier]

    def _make_room(self, tier: str, need: int, exclude: frozenset) -> None:
        """Demote LRU entries until `need` fits in `tier`'s budget."""
        budget = self.budgets.get(tier)
        if budget is None or need <= 0:
            return
        if need > budget:
            raise CapacityError(
                f"{need} bytes exceed the whole {tier!r} budget ({budget})")
        while self._usage[tier] + need > budget:
            victims = [e for e in self._entries.values()
                       if e.tier == tier and not e.pinned
                       and e.key not in exclude
                       and e.key not in self._moving]
            if not victims:
                raise CapacityError(
                    f"tier {tier!r} over budget and nothing evictable "
                    f"(usage={self._usage[tier]}, need={need}, "
                    f"budget={budget})")
            victim = min(victims, key=lambda e: e.last_access)
            self._demote_locked(victim, exclude)

    def _demote_locked(self, e: _Entry, exclude: frozenset) -> None:
        dst = self._colder(e.tier)
        if dst is None:
            raise CapacityError(
                f"cannot evict {e.key!r}: {e.tier!r} is the coldest tier")
        src = e.tier
        # recursive: demotion may itself displace entries in the colder tier
        self._make_room(dst, e.nbytes, exclude | {e.key})
        val = self.backends[src].get(e.key)
        self._charge(dst, e.nbytes)
        self.backends[dst].put(e.key, val)
        e.tier = dst
        e.heat = 0          # demoted data must re-earn promotion
        self._usage[src] -= e.nbytes
        self.backends[src].delete(e.key)
        self.events.append({"op": "demote", "key": e.key, "from": src,
                            "to": dst, "bytes": e.nbytes})

    # -- placement ------------------------------------------------------
    def put(self, key: str, value, tier: str, pinned: bool = False) -> None:
        """Store `value` in `tier`, evicting (demoting) LRU data to fit.

        On CapacityError nothing has changed: a pre-existing copy of the
        key (any tier) is still resident and correctly accounted.
        """
        if tier not in self.backends:
            raise KeyError(f"no backend for tier {tier!r}")
        arr = value if hasattr(value, "nbytes") else np.asarray(value)
        nbytes = int(arr.nbytes)
        deadline = time.monotonic() + 30.0
        while True:
            with self._meta:
                if key not in self._moving:
                    self._put_locked(key, arr, nbytes, tier, pinned)
                    return
            if time.monotonic() > deadline:
                raise RuntimeError(f"staging contention on {key!r}")
            time.sleep(0.001)   # key mid-move; wait for the stager

    def _put_locked(self, key: str, arr, nbytes: int, tier: str,
                    pinned: bool) -> None:
        old = self._entries.get(key)
        freed = old.nbytes if (old is not None and old.tier == tier) else 0
        # reserve before touching the old copy, so a CapacityError here
        # leaves it intact (the "never lost to pressure" guarantee)
        self._make_room(tier, nbytes - freed, frozenset({key}))
        self._usage[tier] -= freed
        self._charge(tier, nbytes)
        try:
            self.backends[tier].put(key, arr)
        except Exception:
            self._usage[tier] += freed - nbytes
            raise
        if old is not None and old.tier != tier:
            self._usage[old.tier] -= old.nbytes
            self.backends[old.tier].delete(key)
        self._clock += 1
        self._entries[key] = _Entry(key, tier, nbytes, pinned=pinned,
                                    last_access=self._clock)

    def delete(self, key: str) -> None:
        with self._meta:
            e = self._entries.pop(key, None)
            if e is None:
                return
            self._usage[e.tier] -= e.nbytes
            self.backends[e.tier].delete(key)

    def adopt(self, key: str, tier: str, nbytes: Optional[int] = None,
              pinned: bool = False) -> None:
        """Register data already sitting in a backend (e.g. a pre-existing
        DataUnit) so it participates in budgets/eviction/heat."""
        if nbytes is None:
            nbytes = self.backends[tier].nbytes(key)
        with self._meta:
            if key in self._entries:
                return
            self._make_room(tier, nbytes, frozenset({key}))
            self._charge(tier, nbytes)
            self._clock += 1
            self._entries[key] = _Entry(key, tier, int(nbytes), pinned=pinned,
                                        last_access=self._clock)

    # -- access ---------------------------------------------------------
    def get(self, key: str) -> np.ndarray:
        """Read a partition from wherever it currently resides.

        Tolerates concurrent staging: a move copies to the destination,
        flips residency, then deletes the source, so on a miss we re-read
        the (updated) residency and retry.
        """
        for _ in range(8):
            with self._meta:
                e = self._entries.get(key)
                tier = e.tier if e else None
            if tier is None:
                break
            try:
                val = self.backends[tier].get(key)
            except (KeyError, FileNotFoundError):
                continue    # raced with a move; residency will have flipped
            self._after_read(key)
            return val
        # last resort: scan every backend (covers unmanaged stragglers)
        for tier in reversed(self.order):
            be = self.backends[tier]
            try:
                if be.exists(key):
                    val = be.get(key)
                    self._after_read(key)
                    return val
            except (KeyError, FileNotFoundError):
                continue
        raise KeyError(key)

    def get_device(self, key: str):
        """Device-resident handle if HBM holds the key; else staged read."""
        import jax
        with self._meta:
            e = self._entries.get(key)
            tier = e.tier if e else None
        be = self.backends.get("device")
        if tier == "device" and be is not None and hasattr(be, "get_device"):
            try:
                arr = be.get_device(key)
                self._after_read(key)
                return arr
            except KeyError:
                pass
            except FileNotFoundError:
                pass
        return jax.device_put(np.asarray(self.get(key)))

    def _after_read(self, key: str) -> None:
        promote_to = None
        with self._meta:
            e = self._entries.get(key)
            if e is None:
                return
            self._touch(e)
            if self.promote_threshold and e.heat >= self.promote_threshold:
                hot = self._hotter(e.tier)
                budget = self.budgets.get(hot) if hot else None
                fits = budget is None or e.nbytes <= budget
                if hot is not None and fits:
                    e.heat = 0
                    promote_to = hot
        if promote_to is not None:
            self.stage_async(key, promote_to)

    # -- pinning --------------------------------------------------------
    def pin(self, keys: Iterable[str] | str) -> None:
        self._set_pinned(keys, True)

    def unpin(self, keys: Iterable[str] | str) -> None:
        self._set_pinned(keys, False)

    def _set_pinned(self, keys, flag: bool) -> None:
        if isinstance(keys, str):
            keys = (keys,)
        with self._meta:
            for k in keys:
                e = self._entries.get(k)
                if e is not None:
                    e.pinned = flag

    # -- staging --------------------------------------------------------
    def stage(self, key: str, tier: str, keep_source: bool = False) -> str:
        """Synchronously move `key` to `tier` (promotion or demotion).

        With keep_source=True the source copy is left behind (untracked,
        cold-tier cache); residency metadata moves to the destination.
        Returns the tier the key resides in afterwards.

        The copy itself runs *outside* the metadata lock (so staging
        overlaps concurrent reads/compute); the lock is taken only to
        reserve destination capacity and to flip residency. Concurrent
        stages of the same key serialize on the `_moving` marker.
        """
        if tier not in self.backends:
            raise KeyError(f"no backend for tier {tier!r}")
        deadline = time.monotonic() + 30.0
        while True:
            with self._meta:
                e = self._entries.get(key)
                if e is None:
                    raise KeyError(key)
                if key not in self._moving:
                    src = e.tier
                    if src == tier:
                        self._touch(e)
                        return tier
                    nbytes = e.nbytes
                    self._make_room(tier, nbytes, frozenset({key}))
                    self._charge(tier, nbytes)
                    self._moving.add(key)
                    break
            if time.monotonic() > deadline:
                raise RuntimeError(f"staging contention on {key!r}")
            time.sleep(0.001)   # another mover has this key; wait it out
        try:
            val = self.backends[src].get(key)      # outside the lock:
            self.backends[tier].put(key, val)      # reads proceed meanwhile
        except Exception:
            with self._meta:
                self._usage[tier] -= nbytes
                self._moving.discard(key)
            raise
        with self._meta:
            e = self._entries.get(key)
            if e is None:
                # deleted mid-move: drop the staged copy + reservation
                self._usage[tier] -= nbytes
                self.backends[tier].delete(key)
                self._moving.discard(key)
                raise KeyError(key)
            e.tier = tier
            self._touch(e)
            self._usage[src] -= nbytes
            if not keep_source:
                self.backends[src].delete(key)
            self._moving.discard(key)
            hot = self.order.index(tier) > self.order.index(src)
            self.events.append({"op": "promote" if hot else "demote",
                                "key": key, "from": src, "to": tier,
                                "bytes": nbytes})
        return tier

    def stage_async(self, key: str, tier: str,
                    keep_source: bool = False) -> Future:
        """Queue a move on the background stager; returns a future resolving
        to the tier the key ends up in (the current tier if the move was
        refused for capacity)."""
        with self._meta:
            fut = self._inflight.get((key, tier))
            if fut is not None and not fut.done():
                return fut
            for k in [k for k, f in self._inflight.items() if f.done()]:
                del self._inflight[k]   # don't retain completed stages
            fut = self._executor.submit(
                self._stage_task, key, tier, keep_source)
            self._inflight[(key, tier)] = fut
            return fut

    def _stage_task(self, key: str, tier: str, keep_source: bool) -> str:
        try:
            return self.stage(key, tier, keep_source=keep_source)
        except CapacityError:
            with self._meta:
                self.events.append({"op": "stage-refused", "key": key,
                                    "to": tier})
            return self.tier_of(key) or tier
        except KeyError:
            return tier   # key deleted while queued; nothing to do

    def prefetch(self, key: str, tier: str) -> Optional[Future]:
        """Async promotion toward `tier` if the key is currently colder."""
        with self._meta:
            e = self._entries.get(key)
            if e is None or tier not in self.backends:
                return None
            if self.order.index(e.tier) >= self.order.index(tier):
                return None
        return self.stage_async(key, tier)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Wait for every queued stage to finish (tests/benchmarks)."""
        with self._meta:
            futs = list(self._inflight.values())
        for f in futs:
            f.result(timeout)

    def close(self) -> None:
        self._executor.shutdown(wait=True)

    def __repr__(self) -> str:
        parts = ", ".join(
            f"{t}={self._usage[t]}/{self.budgets[t] or 'inf'}"
            for t in self.order)
        return f"TierManager({parts})"


def make_tier_manager(*, device_budget: Optional[int] = None,
                      host_budget: Optional[int] = None,
                      root: Optional[str] = None, mesh=None,
                      promote_threshold: int = 4) -> TierManager:
    """Convenience: a host(+file)(+device) hierarchy with common budgets.

    Without `root` the coldest tier is host RAM (no disk side effects);
    with `root` a file tier is added below it.
    """
    from repro.core.memory import make_backend
    backends: Dict[str, StorageBackend] = {}
    if root is not None:
        backends["file"] = make_backend("file", root=root)
    backends["host"] = make_backend("host")
    backends["device"] = make_backend("device", mesh=mesh)
    budgets: Dict[str, Optional[int]] = {}
    if device_budget is not None:
        budgets["device"] = int(device_budget)
    if host_budget is not None:
        budgets["host"] = int(host_budget)
    return TierManager(backends, budgets, promote_threshold=promote_threshold)
