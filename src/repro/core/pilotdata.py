"""PilotDataService: the distributed Pilot-Data layer over per-pilot tiers.

Paper §3.3 / Fig. 5: Pilot-Data manages Data-Units *across* Pilots on
heterogeneous infrastructure, and the Compute-Data-Manager binds CUs
"taking into account the current available Pilots, their utilization and
data locality".  A single TierManager models one pilot's managed memory;
this service is the layer above it, the piece that makes "locality" a
per-pilot fact rather than one shared pool:

  * a **replica registry**: which pilot holds which partition key (each
    pilot's TierManager remains the authority for *which tier* the replica
    currently sits in — demotions inside a pilot never desynchronize the
    registry);
  * **replication**: `replicate` copies a partition into a target pilot's
    managed tiers (pull-through on read misses, explicit via
    `DataUnit.replicate_to_pilot`, async for pre-binding stage-in), with
    per-key stripe locks serializing replicate-vs-invalidate races;
  * **coherent invalidation**: a write or delete of a partition removes
    every pilot replica before/after the home copy changes, so two pilots
    can read the same partition concurrently and never observe a stale
    value after a write completes (the follow-on two-level-storage paper,
    arXiv:1508.01847, motivates exactly this replicated node-local store).

Cross-pilot replica reads (`interconnect=` / `attach_interconnect`): with
a cost model attached (repro.core.scheduling.InterconnectModel — per-link
GB/s + latency between pilots, plus the home re-pull path), the fetch
path prices every way of sourcing a partition and takes the cheapest: a
CU bound to pilot A reads from sibling pilot B's replica over the
modelled link exactly when that beats re-pulling from the home store
(the checkpoint home stays the unpriced last resort).  Without a model
the home-first order is preserved bit-for-bit.

Capacity stays per-pilot: a replica landing in a full pilot demotes that
pilot's own data through *its* hierarchy (device -> host -> file), or is
refused outright when it cannot fit anywhere in the pilot — replication
never silently expands a pilot's memory ask.

Checkpoint home (`checkpoint_dir=` / `attach_checkpoint_store`): the
service can own a durable checkpoint store that acts as a **shared home**
beneath every pilot:

  * `persist(du)` writes a DU's partitions through to the store (async
    via the store's write-behind writer; `flush()` is the barrier), and
    `register(du, persist=True)` does it at registration;
  * the replica fetch path falls back to the checkpoint store when the
    home placement and every live replica are gone — so a CU retried
    after a pilot failure (volatile tiers wiped) restores its partitions
    from checkpoint instead of erroring.  Recovery is lazy: bytes come
    back one partition at a time, as reads pull them through;
  * writes stay coherent: `update_partition` refreshes the persisted
    copy alongside the replica invalidation, and `DataUnit.delete` drops
    it (`drop_persistent=True`), so the store never resurrects deleted
    or stale data.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.memory import TIERS, StorageBackend
from repro.core.memory import checkpoint_store as _checkpoint_store
from repro.core.tiering import CapacityError, TierManager

_N_STRIPES = 32


def _as_nd(val) -> np.ndarray:
    """One conversion per hop: the fetch/replicate/persist plane already
    carries ndarrays (read-only views since PR 8), so `np.asarray` is a
    no-op for them — but routing every hop through this helper keeps the
    \"convert at most once\" contract greppable and never re-materializes
    a view that is already an ndarray."""
    return val if isinstance(val, np.ndarray) else np.asarray(val)


class PilotDataService:
    """Registry + mover for per-pilot DataUnit replicas.

    Pilots join with `register_pilot` (they must carry a TierManager — the
    per-pilot managed memory provisioned from `memory_gb`); DataUnits join
    with `register`, after which their pilot-aware reads, prefetches, and
    coherence flow through this service.
    """

    def __init__(self, max_workers: int = 4,
                 checkpoint_dir: Optional[str] = None,
                 interconnect=None):
        self._managers: Dict[str, TierManager] = {}   # pilot id -> manager
        self._replicas: Dict[str, Set[str]] = {}      # key -> pilot ids
        self._dus: Dict[str, object] = {}             # du name -> DataUnit
        self._lock = threading.Lock()                 # registry metadata
        self._stripes = [threading.Lock() for _ in range(_N_STRIPES)]
        self._inflight: Dict[tuple, Future] = {}
        self._closed = False
        self._executor = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="pds-replicator")
        self.events: List[dict] = []
        self.counters: Dict[str, int] = {
            "replications": 0, "pulls": 0, "invalidations": 0,
            "replicate_refused": 0, "checkpoint_restores": 0, "persists": 0,
            "sibling_reads": 0, "home_reads": 0, "repairs": 0}
        # replication-factor repair (PR 7): per-DU target replica counts,
        # the supervisor-driven avoid set (quarantined pilots are never
        # read from NOR repaired onto), and the background repair worker
        self._repl_targets: Dict[str, tuple] = {}     # du.name -> (du, n)
        self._avoid: Set[str] = set()
        self._repair_thread: Optional[threading.Thread] = None
        self._repair_stop = threading.Event()
        self._repair_depth = 0
        # cost-modelled cross-pilot reads (repro.core.scheduling.
        # InterconnectModel): with a model attached, _fetch sources a
        # partition from the CHEAPEST modelled path — a sibling pilot's
        # replica over its link, or a home re-pull — instead of always
        # going home first.  None preserves the home-first PR 3 order.
        self.interconnect = interconnect
        # the shared durable home (see module docstring); per-directory
        # shared instance, so pilots spilling to the same dir and this
        # service recover from ONE consistent store.  The service never
        # closes it — pilots naming the same dir hold the same instance,
        # and a second live instance over one directory would clobber the
        # manifest — it only flushes (the durability barrier).
        self.checkpoint_store: Optional[StorageBackend] = (
            _checkpoint_store(checkpoint_dir) if checkpoint_dir else None)

    def attach_checkpoint_store(self, store: StorageBackend
                                ) -> "PilotDataService":
        """Use an existing (possibly shared) checkpoint store as the
        durable home; the caller keeps ownership of its lifecycle."""
        self.checkpoint_store = store
        return self

    def attach_interconnect(self, model) -> "PilotDataService":
        """Enable cost-modelled cross-pilot replica reads (see
        repro.core.scheduling.InterconnectModel)."""
        self.interconnect = model
        return self

    # -- membership ------------------------------------------------------
    def register_pilot(self, pilot) -> "PilotDataService":
        tm = getattr(pilot, "tier_manager", None)
        if tm is None:
            raise ValueError(
                f"pilot {pilot.id} has no TierManager: provision it with "
                "memory_gb (or attach_tier_manager) before registering")
        with self._lock:
            self._managers[pilot.id] = tm
        return self

    def unregister_pilot(self, pilot_id: str) -> None:
        """Forget a pilot: its manager stops serving replicas and its ids
        leave the registry (the data in its tiers is the releaser's to
        clean up, usually via PilotCompute.cancel -> TierManager.close)."""
        with self._lock:
            self._managers.pop(pilot_id, None)
            for pids in self._replicas.values():
                pids.discard(pilot_id)

    def register(self, du, persist: bool = False,
                 replication: int = 0):  # noqa: F821 - fwd ref
        """Bind a DataUnit to this service.  `replication` > 0 declares a
        target replica count per partition: the background repair worker
        (see `start_repair`) re-replicates any partition that falls below
        it — e.g. after a pilot death wiped one copy — from surviving
        replicas or the checkpoint home.  0 (the default) keeps the
        historical demand-driven behavior: replicas appear only where
        reads pull them."""
        du.pilot_data_service = self
        with self._lock:
            self._dus[du.name] = du
        if persist:
            self.persist(du)
        if replication > 0:
            with self._lock:
                self._repl_targets[du.name] = (du, int(replication))
        return du

    def data_units(self) -> List:
        """Every DataUnit bound to this service (evacuation and
        rebalancing sweep these — the replica registry alone maps keys,
        not partitions)."""
        with self._lock:
            return list(self._dus.values())

    # -- supervisor liveness filter --------------------------------------
    def avoid_pilot(self, pilot_id: str) -> None:
        """Quarantine a pilot for data sourcing: fetches and repair stop
        reading from (and repairing onto) its replicas until readmitted.
        The registry itself is untouched — if the pilot recovers, its
        replicas are still valid."""
        with self._lock:
            self._avoid.add(pilot_id)

    def readmit_pilot(self, pilot_id: str) -> None:
        with self._lock:
            self._avoid.discard(pilot_id)

    @property
    def avoided(self) -> frozenset:
        with self._lock:
            return frozenset(self._avoid)

    def live_holders(self, key: str) -> List[str]:
        """`holders` minus the quarantined pilots — the only holder list
        repair and cost planning may source from."""
        with self._lock:
            avoid = set(self._avoid)
        return [pid for pid in self.holders(key) if pid not in avoid]

    # -- durable home ----------------------------------------------------
    def persist(self, du, parts: Optional[Sequence[int]] = None,
                flush: bool = False) -> List[int]:
        """Write partitions of `du` through to the checkpoint store (the
        durable home replica all pilots can recover from).  Writes ride
        the store's async writer; pass flush=True (or call
        `flush_checkpoints`) for the durability barrier.  Returns the
        partition indices persisted (missing ones are skipped)."""
        store = self.checkpoint_store
        if store is None:
            raise RuntimeError("no checkpoint store attached: construct "
                               "PilotDataService(checkpoint_dir=...) or "
                               "attach_checkpoint_store first")
        done: List[int] = []
        for i in (range(du.num_partitions) if parts is None else parts):
            try:
                val = du.partition(i)
            except (KeyError, FileNotFoundError):
                continue
            store.put(du._key(i), _as_nd(val))
            done.append(i)
        with self._lock:
            self.counters["persists"] += len(done)
        if done:
            self.events.append({"op": "persist", "du": du.name,
                                "parts": len(done)})
        if flush:
            self.flush_checkpoints()
        return done

    def flush_checkpoints(self) -> None:
        """Durability barrier: every persisted byte on disk, manifest
        fsync'd (no-op without a store)."""
        store = self.checkpoint_store
        if store is not None and hasattr(store, "flush"):
            store.flush()

    def knows(self, pilot_id: str) -> bool:
        return pilot_id in self._managers

    def pilot_ids(self) -> List[str]:
        with self._lock:
            return list(self._managers)

    def manager_for(self, pilot_id: str) -> Optional[TierManager]:
        return self._managers.get(pilot_id)

    # -- queries ---------------------------------------------------------
    def _stripe(self, key: str) -> threading.Lock:
        return self._stripes[hash(key) % _N_STRIPES]

    def _holds(self, pilot_id: str, key: str) -> bool:
        with self._lock:
            return pilot_id in self._replicas.get(key, ())

    def holders(self, key: str) -> List[str]:
        """Pilots holding a replica of `key`, in registration order."""
        with self._lock:
            pids = self._replicas.get(key, ())
            return [pid for pid in self._managers if pid in pids]

    def tier_on(self, key: str, pilot_id: str) -> Optional[str]:
        """The tier `key` currently occupies inside `pilot_id` (live from
        the pilot's TierManager, so demotions are always reflected)."""
        if not self._holds(pilot_id, key):
            return None
        tm = self._managers.get(pilot_id)
        return tm.tier_of(key) if tm is not None else None

    def residency(self, du, pilot_id: str) -> Dict[str, int]:
        """Partition count per tier of `du` inside one pilot."""
        out: Dict[str, int] = {}
        for i in range(du.num_partitions):
            t = self.tier_on(du._key(i), pilot_id)
            if t is not None:
                out[t] = out.get(t, 0) + 1
        return out

    def resident_fraction(self, du, pilot_id: str, tier: str) -> float:
        if du.num_partitions == 0:
            return 0.0
        return self.residency(du, pilot_id).get(tier, 0) / du.num_partitions

    def local_fraction(self, du, pilot_id: str) -> float:
        """Fraction of `du` resident in the pilot at *any* tier."""
        if du.num_partitions == 0:
            return 0.0
        return sum(self.residency(du, pilot_id).values()) / du.num_partitions

    def best_pilot(self, key: str,
                   candidates: Sequence[str]) -> Optional[str]:
        """The candidate holding `key` at the hottest tier (ties resolve to
        the earliest candidate, keeping placement deterministic)."""
        best, best_rank = None, -1
        for pid in candidates:
            t = self.tier_on(key, pid)
            if t is None:
                continue
            rank = TIERS.index(t)
            if rank > best_rank:
                best, best_rank = pid, rank
        return best

    # -- replication -----------------------------------------------------
    def replicate(self, du, i: int, pilot_id: str,
                  tier: str = "device", pin: bool = False) -> str:
        """Ensure partition `i` of `du` is resident in `pilot_id`, copying
        it in from the home placement (or another replica) when absent and
        promoting it toward `tier` when already held colder.  Returns the
        tier the replica occupies; raises CapacityError when the partition
        cannot fit anywhere in the pilot's hierarchy.  ``pin=True`` marks
        the replica eviction-exempt inside that pilot (a serving fleet's
        model shards must survive KV-page churn)."""
        tm = self._managers.get(pilot_id)
        if tm is None:
            raise KeyError(f"unknown pilot {pilot_id!r}")
        key = du._key(i)
        with self._stripe(key):
            if self._holds(pilot_id, key) and tm.tier_of(key) is not None:
                if pin:
                    tm.pin(key)
                if tier in tm.backends:
                    try:
                        return tm.stage(key, tier)   # no-op when already hot
                    except CapacityError:
                        pass
                return tm.tier_of(key) or tier
            val = self._fetch(du, i, exclude=pilot_id, dest=pilot_id)
            dst = tier if tier in tm.backends else tm.order[-1]
            try:
                tm.put(key, _as_nd(val), dst, pinned=pin)
            except CapacityError:
                with self._lock:
                    self.counters["replicate_refused"] += 1
                self.events.append({"op": "replicate-refused", "key": key,
                                    "pilot": pilot_id, "tier": dst})
                raise
            with self._lock:
                self._replicas.setdefault(key, set()).add(pilot_id)
                self.counters["replications"] += 1
            self.events.append({"op": "replicate", "key": key,
                                "pilot": pilot_id, "tier": dst})
            return dst

    def replicate_async(self, du, i: int, pilot_id: str,
                        tier: str = "device") -> Future:
        """Queue `replicate` on the background pool (pre-binding stage-in).
        The future resolves to the landed tier, or None when the copy was
        refused for capacity / the partition vanished — never raises."""
        with self._lock:
            if self._closed:
                fut: Future = Future()
                fut.set_result(None)
                return fut
            token = (du._key(i), pilot_id)
            fut = self._inflight.get(token)
            if fut is not None and not fut.done():
                return fut
            for k in [k for k, f in self._inflight.items() if f.done()]:
                del self._inflight[k]
            fut = self._executor.submit(
                self._replicate_task, du, i, pilot_id, tier)
            self._inflight[token] = fut
            return fut

    def _replicate_task(self, du, i, pilot_id, tier) -> Optional[str]:
        try:
            return self.replicate(du, i, pilot_id, tier)
        except (CapacityError, KeyError):
            return None

    def replicate_to_pilot(self, du, pilot_id: str,
                           parts: Optional[Sequence[int]] = None,
                           tier: str = "device",
                           pin: bool = False) -> Dict[int, str]:
        """Synchronously replicate `parts` (default: all partitions) of
        `du` into a pilot; returns {partition: landed tier} for the copies
        that fit (capacity-refused or vanished partitions are skipped, not
        forced; an unregistered pilot raises).  ``pin=True`` marks the
        landed replicas eviction-exempt in that pilot."""
        if pilot_id not in self._managers:
            raise KeyError(f"unknown pilot {pilot_id!r}: register it with "
                           "register_pilot first")
        out: Dict[int, str] = {}
        for i in (range(du.num_partitions) if parts is None else parts):
            try:
                out[i] = self.replicate(du, i, pilot_id, tier, pin=pin)
            except (CapacityError, KeyError):
                continue
        return out

    # -- replication-factor repair ---------------------------------------
    def _live_replicas(self, du, i: int) -> List[str]:
        """Pilots verifiably holding partition `i` right now: registered,
        not quarantined, and their TierManager still has the bytes (a
        registry entry can outlive the data after lose_volatile)."""
        key = du._key(i)
        out: List[str] = []
        for pid in self.live_holders(key):
            tm = self._managers.get(pid)
            if tm is None or getattr(tm, "_lost", False):
                continue
            if tm.tier_of(key) is not None:
                out.append(pid)
        return out

    def under_replicated(self) -> List[tuple]:
        """Every (du, partition, current, target) below its declared
        replication target, given the pilots usable right now.  Targets
        are clamped to the usable fleet size — 2 replicas on a 1-pilot
        fleet is satisfied by 1, not permanently 'under'."""
        with self._lock:
            targets = list(self._repl_targets.values())
            avoid = set(self._avoid)
            usable = [pid for pid, tm in self._managers.items()
                      if pid not in avoid and not getattr(tm, "_lost", False)]
        out: List[tuple] = []
        for du, target in targets:
            eff = min(target, len(usable))
            if eff <= 0:
                continue
            for i in range(du.num_partitions):
                cur = len(self._live_replicas(du, i))
                if cur < eff:
                    out.append((du, i, cur, eff))
        return out

    def repair_partition(self, du, i: int, target: int,
                         tier: str = "host") -> int:
        """Bring partition `i` up to `target` live replicas, copying from
        surviving replicas or the checkpoint home (never from a
        quarantined pilot — the fetch path filters them).  New homes are
        chosen cheapest-first by the InterconnectModel when one is
        attached (re-replication is bulk traffic; it should ride the
        cheap links), else in registration order.  Returns the number of
        replicas created."""
        cur = set(self._live_replicas(du, i))
        need = target - len(cur)
        if need <= 0:
            return 0
        with self._lock:
            avoid = set(self._avoid)
            cands = [pid for pid, tm in self._managers.items()
                     if pid not in avoid and pid not in cur
                     and not getattr(tm, "_lost", False)]
        if not cands:
            return 0
        ic = self.interconnect
        if ic is not None and cur:
            nb = self.partition_nbytes(du, i)
            cands.sort(key=lambda pid: min(
                [ic.transfer_cost(src, pid, nb) for src in cur]
                + [ic.home_cost(nb)]))
        made = 0
        key = du._key(i)
        for pid in cands[:need]:
            try:
                landed = self.replicate(du, i, pid, tier)
            except (CapacityError, KeyError, FileNotFoundError):
                continue
            made += 1
            with self._lock:
                self.counters["repairs"] += 1
            self.events.append({"op": "repair", "key": key, "pilot": pid,
                                "tier": landed})
        return made

    def repair_once(self) -> int:
        """One repair sweep: re-replicate everything currently below
        target.  Returns replicas created (0 = fully replicated)."""
        work = self.under_replicated()
        self._repair_depth = len(work)
        made = 0
        for du, i, _cur, target in work:
            if self._repair_stop.is_set() and self._repair_thread is not None:
                break
            made += self.repair_partition(du, i, target)
        self._repair_depth = len(self.under_replicated())
        return made

    def start_repair(self, interval_s: float = 0.1) -> "PilotDataService":
        """Start the background repair worker (idempotent).  It sweeps
        every `interval_s`, so detection-to-repair latency is bounded by
        one interval plus copy time."""
        if self._repair_thread is not None and self._repair_thread.is_alive():
            return self
        self._repair_stop.clear()

        def _loop():
            while not self._repair_stop.wait(interval_s):
                try:
                    self.repair_once()
                except Exception:   # noqa: BLE001 - repair races teardown
                    pass

        self._repair_thread = threading.Thread(
            target=_loop, daemon=True, name="pds-repair")
        self._repair_thread.start()
        return self

    def stop_repair(self, timeout: float = 5.0) -> None:
        self._repair_stop.set()
        t = self._repair_thread
        if t is not None:
            t.join(timeout)
        self._repair_thread = None

    @property
    def repair_queue_depth(self) -> int:
        """Under-replicated partitions seen at the last repair sweep."""
        return self._repair_depth

    def replication_stats(self) -> Dict[str, dict]:
        """Per-DU current-vs-target replication: partition -> live replica
        count, the declared target, and how many partitions are below it."""
        with self._lock:
            targets = list(self._repl_targets.values())
        out: Dict[str, dict] = {}
        for du, target in targets:
            per_part = {i: len(self._live_replicas(du, i))
                        for i in range(du.num_partitions)}
            out[du.name] = {
                "target": target,
                "per_partition": per_part,
                "under": sum(1 for c in per_part.values() if c < target),
            }
        return out

    # -- scale-in drain / rebalancing ------------------------------------
    def holder_load(self, pilot_id: str) -> Dict[str, int]:
        """How much replica state a pilot is carrying right now:
        ``{"partitions": n, "nbytes": total}`` of *live* replicas (the
        registry entry must be backed by bytes in the pilot's tiers).
        The autoscaler's victim choice and the rebalancer's skew
        detection both rank pilots by this."""
        tm = self._managers.get(pilot_id)
        with self._lock:
            keys = [k for k, pids in self._replicas.items()
                    if pilot_id in pids]
        parts, nbytes = 0, 0
        if tm is not None and not getattr(tm, "_lost", False):
            for k in keys:
                if tm.tier_of(k) is None:
                    continue
                parts += 1
                try:
                    nbytes += int(tm.entry_nbytes(k))
                except KeyError:
                    continue
        return {"partitions": parts, "nbytes": nbytes}

    def _home_has(self, du, i: int) -> bool:
        """Whether the DU's home placement still holds partition `i`
        (metadata check — never pulls bytes through a throttled home)."""
        key = du._key(i)
        tm = getattr(du, "tier_manager", None)
        if tm is not None:
            return tm.tier_of(key) is not None
        try:
            return bool(du._backend(du.tier).exists(key))
        except Exception:   # noqa: BLE001 - a released home tier == gone
            return False

    def drop_replica(self, du, i: int, pilot_id: str) -> bool:
        """Remove ONE pilot's replica of partition `i` — the second half
        of a migration (`invalidate` drops every replica; a rebalance
        move must drop only the source's).  Stripe-locked against
        replicate/invalidate races.  Like `invalidate`, a durable copy
        that shared the pilot's spill store is re-persisted from the
        surviving sources, so dropping a replica never costs durability.
        Returns True when a registry entry was actually removed."""
        key = du._key(i)
        store = self.checkpoint_store
        with self._stripe(key):
            with self._lock:
                pids = self._replicas.get(key)
                held = pids is not None and pilot_id in pids
                if held:
                    pids.discard(pilot_id)
                    if not pids:
                        self._replicas.pop(key, None)
            tm = self._managers.get(pilot_id)
            if tm is None or tm.tier_of(key) is None:
                return held
            persisted = store is not None and store.exists(key)
            snap = None
            if persisted:
                # the replica may BE the persisted copy (demoted into a
                # spill tier sharing the store's directory): hold a view
                # of the bytes before delete so we can re-persist
                try:
                    snap = tm.get(key)
                except (KeyError, FileNotFoundError):
                    snap = None
            try:
                tm.delete(key)
            except Exception:   # noqa: BLE001 - a dying manager is fine
                pass
            if persisted and not store.exists(key):
                # the delete purged the shared durable copy: restore it
                # from the held view, or home / surviving replicas
                try:
                    val = (np.array(snap) if snap is not None
                           else self._fetch(du, i, exclude=pilot_id))
                    store.put(key, _as_nd(val))
                except KeyError:
                    pass
        self.events.append({"op": "drop-replica", "key": key,
                            "pilot": pilot_id})
        return held

    def evacuate_pilot(self, pilot_id: str, tier: str = "host") -> dict:
        """The data half of the autoscaler's drain protocol: make every
        partition resident in `pilot_id` survivable without it, then drop
        the pilot's replicas.

        Per resident partition, in order of preference: (1) it already
        has another live replica, a readable home placement, or a durable
        checkpoint copy — nothing to move; (2) migrate it to the
        cheapest other pilot(s) (priced by the InterconnectModel when one
        is attached, via the same `replicate` machinery repair uses), also
        topping a declared ``replication=`` target back up *excluding*
        the victim; (3) checkpoint-flush it as a last resort.  A
        partition none of those can save is left in place and counted in
        ``failed`` — the caller must then abort the release.

        Returns ``{"partitions": scanned, "migrated": n, "flushed": n,
        "dropped": n, "failed": n}``."""
        out = {"partitions": 0, "migrated": 0, "flushed": 0,
               "dropped": 0, "failed": 0}
        tm = self._managers.get(pilot_id)
        if tm is None:
            return out
        with self._lock:
            dus = list(self._dus.values())
            targets = {name: n for name, (_du, n) in
                       self._repl_targets.items()}
        flush_needed = False
        for du in dus:
            target = targets.get(du.name, 0)
            for i in range(du.num_partitions):
                key = du._key(i)
                if not self._holds(pilot_id, key) or tm.tier_of(key) is None:
                    continue
                out["partitions"] += 1
                survivors = [p for p in self._live_replicas(du, i)
                             if p != pilot_id]
                home_ok = self._home_has(du, i)
                store = self.checkpoint_store
                ckpt_ok = store is not None and store.exists(key)
                # live copies required after the victim leaves: the
                # declared replication target, and at least one anywhere
                # when no durable/home source could restore the bytes
                need = target
                if not (home_ok or ckpt_ok):
                    need = max(1, need)
                missing = need - len(survivors)
                if missing > 0:
                    with self._lock:
                        avoid = set(self._avoid)
                        cands = [pid for pid, m in self._managers.items()
                                 if pid != pilot_id and pid not in avoid
                                 and pid not in survivors
                                 and not getattr(m, "_lost", False)]
                    ic = self.interconnect
                    if ic is not None and cands:
                        nb = self.partition_nbytes(du, i)
                        cands.sort(key=lambda pid:
                                   ic.transfer_cost(pilot_id, pid, nb))
                    for pid in cands:
                        try:
                            self.replicate(du, i, pid, tier)
                        except (CapacityError, KeyError,
                                FileNotFoundError):
                            continue
                        survivors.append(pid)
                        out["migrated"] += 1
                        if len(survivors) >= need:
                            break
                if not survivors and not (home_ok or ckpt_ok):
                    # nowhere to migrate: checkpoint-flush the victim's
                    # own bytes (it may hold the only copy — the home
                    # read `persist` does would miss), the paper's
                    # durable-tier escape hatch for scale-in
                    try:
                        if store is None:
                            raise KeyError(key)
                        store.put(key, _as_nd(tm.get(key)))
                    except (KeyError, FileNotFoundError):
                        out["failed"] += 1
                        continue
                    with self._lock:
                        self.counters["persists"] += 1
                    out["flushed"] += 1
                    flush_needed = True
                self.drop_replica(du, i, pilot_id)
                out["dropped"] += 1
        if flush_needed:
            self.flush_checkpoints()    # durability barrier before release
        self.events.append({"op": "evacuate", "pilot": pilot_id, **out})
        return out

    # -- reads -----------------------------------------------------------
    def read(self, du, i: int, pilot_id: str, device: bool = False,
             pull_tier: str = "device"):
        """Read partition `i` *as the pilot*: hit the pilot's own tiers when
        a replica is resident (recording heat in that pilot's manager),
        else pull the partition through into the pilot (replicate-on-read)
        so subsequent iterations stay node-local.  A partition too large to
        cache in the pilot is served from its home without caching."""
        key = du._key(i)
        tm = self._managers.get(pilot_id)
        if tm is None:
            return du.partition_device(i) if device else du.partition(i)
        if self._holds(pilot_id, key):
            try:
                return tm.get_device(key) if device else tm.get(key)
            except (KeyError, FileNotFoundError):
                pass    # invalidated under us; fall through to a re-pull
        try:
            self.replicate(du, i, pilot_id, pull_tier)
            return tm.get_device(key) if device else tm.get(key)
        except CapacityError:
            # too large to cache in the pilot: serve without caching, via
            # the full fetch chain (home, live replicas, checkpoint home)
            with self._lock:
                self.counters["pulls"] += 1
            val = self._fetch(du, i, dest=pilot_id)
            if device:
                import jax
                return jax.device_put(_as_nd(val))
            return _as_nd(val)
        except (KeyError, FileNotFoundError):
            # deleted while pulling: the home read gives the truth (and
            # raises KeyError if the partition is truly gone)
            return du.partition_device(i) if device else du.partition(i)

    def partition_nbytes(self, du, i: int) -> int:
        """Best-effort partition size for cost modelling: replica-holder
        metadata first (an in-memory dict read), then the home placement
        (FileBackend answers from the .npy header, so a throttled home
        profile is NOT charged just to price a transfer).  0 when nobody
        can say — the cost comparison then reduces to the links' fixed
        latencies."""
        key = du._key(i)
        for pid in self.holders(key):
            tm = self._managers.get(pid)
            if tm is None:
                continue
            try:
                n = tm.entry_nbytes(key)
            except KeyError:
                continue
            if n:
                return int(n)
        try:
            return int(du.partition_nbytes(i))
        except (KeyError, FileNotFoundError, AttributeError):
            return 0

    def _fetch(self, du, i: int, exclude: Optional[str] = None,
               dest: Optional[str] = None):
        """Source a partition's bytes for `dest` (the pilot pulling it).

        Without an InterconnectModel (or without a destination pilot) the
        PR 3 order applies: home placement first, then any other replica
        holder, then the durable checkpoint home (survives a released
        home tier AND pilot loss — the recovery path a retried CU
        restores through).

        With a model attached, the home re-pull and every sibling replica
        are priced (link bandwidth + latency x partition size) and tried
        cheapest-first — the ROADMAP's cross-pilot replica read: a CU
        bound to pilot A reads from sibling pilot B's memory exactly when
        the modelled link beats going back to the home store.  Ties break
        toward home (the historical order); the checkpoint store stays
        the unpriced last resort either way."""
        key = du._key(i)
        ic = self.interconnect
        # quarantined pilots are never read from: a suspect's bytes may be
        # mid-loss, and touching its TierManager can block on a dead node
        sibs = [pid for pid in self.live_holders(key)
                if pid != exclude and pid != dest]
        # (modelled cost, tiebreak, source pilot or None=home)
        if ic is not None and dest is not None and sibs:
            nbytes = self.partition_nbytes(du, i)
            plan = [(ic.home_cost(nbytes), 0, None)]
            plan += [(ic.transfer_cost(pid, dest, nbytes), 1, pid)
                     for pid in sibs]
            plan.sort(key=lambda c: (c[0], c[1]))
            costed = True
        else:
            plan = [(0.0, 0, None)] + [(0.0, 1, pid) for pid in sibs]
            costed = False
        for cost, _, pid in plan:
            if pid is None:
                try:
                    val = du.partition(i)
                except (KeyError, FileNotFoundError):
                    continue
                if costed:
                    with self._lock:
                        self.counters["home_reads"] += 1
                return val
            tm = self._managers.get(pid)
            if tm is None:
                continue
            try:
                val = tm.get(key)
            except (KeyError, FileNotFoundError):
                continue
            if costed:
                # size from the cost plan's header-only/metadata estimate —
                # never re-materialize the (possibly mmap'd) value just to
                # measure it; val is always an ndarray view here anyway
                ic.charge(pid, dest, nbytes or int(val.nbytes))
                with self._lock:
                    self.counters["sibling_reads"] += 1
                self.events.append({"op": "sibling-read", "key": key,
                                    "src": pid, "dst": dest, "cost": cost})
            return val
        store = self.checkpoint_store
        if store is not None:
            try:
                val = store.get(key)
            except (KeyError, FileNotFoundError):
                val = None
            if val is not None:
                with self._lock:
                    self.counters["checkpoint_restores"] += 1
                self.events.append({"op": "checkpoint-restore", "key": key})
                return val
        raise KeyError(key)

    # -- coherence -------------------------------------------------------
    def invalidate(self, du, i: Optional[int] = None,
                   keep: Optional[str] = None,
                   drop_persistent: bool = False) -> int:
        """Drop pilot replicas of partition `i` (or of every partition) —
        the write/delete coherence path.  `keep` preserves one pilot's
        replica (used when that pilot just produced the new value).

        The durable home stays coherent too: on a write
        (drop_persistent=False) a persisted copy is refreshed from the
        new home bytes, so recovery never restores a stale value; on a
        delete (drop_persistent=True) the persisted copy is removed, so
        the store cannot resurrect deleted data.  Returns the number of
        replicas removed."""
        idxs = range(du.num_partitions) if i is None else (i,)
        store = self.checkpoint_store
        removed = 0
        for j in idxs:
            key = du._key(j)
            with self._stripe(key):
                # snapshot BEFORE dropping replicas: a replica manager's
                # delete also purges its untracked durable copies, which
                # may live in this very store when the pilots spill to it
                persisted = store is not None and store.exists(key)
                with self._lock:
                    pids = self._replicas.pop(key, set())
                    if keep is not None and keep in pids:
                        self._replicas[key] = {keep}
                dropped = 0
                for pid in pids:
                    if pid == keep:
                        continue
                    tm = self._managers.get(pid)
                    if tm is not None:
                        tm.delete(key)
                        dropped += 1
                if persisted:
                    if drop_persistent:
                        store.delete(key)
                    else:
                        try:
                            store.put(key, _as_nd(du.partition(j)))
                        except (KeyError, FileNotFoundError):
                            store.delete(key)   # home gone: don't go stale
                if dropped:
                    self.events.append({"op": "invalidate", "key": key,
                                        "replicas": dropped})
                removed += dropped
        with self._lock:
            self.counters["invalidations"] += removed
        return removed

    # -- telemetry / shutdown -------------------------------------------
    def stats(self) -> Dict[str, dict]:
        """Per-pilot TierManager stats (usage/budget/entries per tier)."""
        with self._lock:
            managers = dict(self._managers)
        return {pid: tm.stats() for pid, tm in managers.items()}

    def drain(self, timeout: Optional[float] = None) -> None:
        with self._lock:
            futs = list(self._inflight.values())
        for f in futs:
            if not f.cancelled():
                try:
                    f.result(timeout)
                except Exception:   # noqa: BLE001 - refusals are normal
                    pass

    def close(self) -> None:
        """Stop the replicator pool and flush the checkpoint store so
        every persisted byte is durable and the manifest is fsync'd.  The
        store itself stays open (it is shared per directory with the
        pilots' spill tiers; its writer thread is a daemon) — closing it
        here while another holder still wrote to it would fork two live
        manifests over one directory.  Idempotent; registry and store
        stay readable."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.stop_repair()
        self._executor.shutdown(wait=True, cancel_futures=True)
        with self._lock:
            self._inflight.clear()
        self.flush_checkpoints()

    def __repr__(self) -> str:
        with self._lock:
            return (f"PilotDataService(pilots={len(self._managers)}, "
                    f"replicated_keys={len(self._replicas)})")
