"""Zero-copy buffer views for the Pilot-Data transport plane.

The paper's in-memory Pilot-Data argument (§4) only holds if the access
path is fast: retaining a partition in a hot tier buys nothing when every
hop through the replica/fetch plane re-materializes the bytes with a
memcpy.  This module is the data plane's view abstraction:

  * ``Buf`` — a read-only view over bytes some tier already owns
    (``memoryview``-style semantics for ndarrays: ``np.memmap`` over
    ``FileBackend``/``CheckpointBackend`` files, a plain aliasing view
    over ``HostMemoryBackend`` arrays, a dlpack view over device-tier
    ``jax.Array``s), carrying provenance (``source`` tier) and ownership.
    ``get``/``fetch``/``replicate``/demote/promote move Bufs; bytes are
    copied only on mutation (``Buf.copy()``) or on a tier crossing that
    genuinely requires materialization;
  * the **mutation contract**: every view the plane hands out is
    read-only (``writeable=False``).  Writing into a fetched partition
    raises instead of silently corrupting a store; callers that need a
    scratch buffer take ``Buf.copy()`` (or ``DataUnit.partition_copy``).
    Internal moves are copy-first/delete-last, and a dropped source only
    loses the *store's* reference — a reader's live view pins the backing
    bytes (numpy base / mmap'd inode / dlpack capsule), so demotion,
    eviction, and repair can never mutate bytes under a reader;
  * ``TransportStats`` — the plane's global ``bytes_viewed`` /
    ``bytes_copied`` counters (plus per-codec encode/decode counts fed by
    repro.core.codecs), surfaced through ``session.stats()["transport"]``
    so the view-vs-copy ratio is a first-class benchmark quantity;
  * a process-wide ``zero_copy`` switch with a ``copy_mode()`` context
    manager: benchmarks measure the copy baseline by flipping the same
    plane into materialize-always mode instead of forking the transport.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional

import numpy as np


class TransportStats:
    """Global data-plane movement counters.

    Telemetry, not accounting: increments are plain (GIL-atomic in the
    repo's established sense — a racing pair may drop one count, never
    corrupt state), so the hot read path pays zero lock acquisitions for
    its counters — the same trade the TierManager's sharded access
    ledger and the WorkerPool's ``executed`` counter already make.
    """

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.bytes_viewed = 0
        self.bytes_copied = 0
        self.views = 0
        self.copies = 0
        self.codec: Dict[str, int] = {}

    def record_view(self, nbytes: int) -> None:
        self.bytes_viewed += int(nbytes)
        self.views += 1

    def record_copy(self, nbytes: int) -> None:
        self.bytes_copied += int(nbytes)
        self.copies += 1

    def record_codec(self, name: str, op: str) -> None:
        k = f"{name}.{op}"
        self.codec[k] = self.codec.get(k, 0) + 1

    def snapshot(self) -> dict:
        return {"bytes_viewed": self.bytes_viewed,
                "bytes_copied": self.bytes_copied,
                "views": self.views, "copies": self.copies,
                "codec": dict(self.codec)}


STATS = TransportStats()

# process-wide switch: True (default) = the plane hands out views where
# the backing store allows it; False = every read materializes a fresh
# copy (the pre-PR-8 behavior, kept as the measurable baseline)
_zero_copy = True


def zero_copy_enabled() -> bool:
    return _zero_copy


def set_zero_copy(enabled: bool) -> None:
    global _zero_copy
    _zero_copy = bool(enabled)


@contextlib.contextmanager
def copy_mode():
    """Temporarily force materialize-always reads (benchmark baseline)."""
    global _zero_copy
    prev = _zero_copy
    _zero_copy = False
    try:
        yield
    finally:
        _zero_copy = prev


def as_view(arr: np.ndarray, count: bool = True) -> np.ndarray:
    """A read-only aliasing view of `arr` (no bytes move).  The caller's
    array is untouched — only the returned view is write-protected."""
    v = arr.view()
    v.setflags(write=False)
    if count:
        STATS.record_view(v.nbytes)
    return v


def materialize(arr, count: bool = True) -> np.ndarray:
    """An owned, writable host copy of `arr` (the explicit copy hop)."""
    out = np.array(arr)     # always copies, drops the mmap/dlpack base
    if count:
        STATS.record_copy(out.nbytes)
    return out


def device_view(arr) -> Optional[np.ndarray]:
    """Zero-copy host view of a device-tier array via dlpack, or None
    when the buffer is not host-addressable (real HBM: the tier crossing
    then genuinely requires a copy and the caller falls back)."""
    try:
        v = np.from_dlpack(arr)
    except (TypeError, RuntimeError, BufferError, ValueError):
        return None
    if v.flags.writeable:       # defensive: exporters should mark RO
        v = v.view()
        v.setflags(write=False)
    STATS.record_view(v.nbytes)
    return v


class Buf:
    """A read-only view over partition bytes plus provenance.

    ``array`` is the zero-copy (or, in copy mode, materialized) ndarray;
    ``source`` names the tier/backend the bytes came from; ``owned`` says
    whether the bytes were materialized for this Buf (True) or alias a
    store's buffer (False).  ``np.asarray(buf)`` / ``jnp.asarray(buf)``
    work directly via ``__array__``.
    """

    __slots__ = ("array", "source", "owned")

    def __init__(self, array: np.ndarray, source: str = "",
                 owned: bool = False):
        self.array = array
        self.source = source
        self.owned = owned

    # -- ndarray-shaped surface ------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(self.array.nbytes)

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def shape(self):
        return self.array.shape

    def __array__(self, dtype=None, copy=None):
        a = self.array
        if dtype is not None and a.dtype != dtype:
            return a.astype(dtype)
        if copy:
            return np.array(a)
        return a

    def __len__(self) -> int:
        return len(self.array)

    # -- the mutation contract -------------------------------------------
    def view(self) -> np.ndarray:
        """The read-only ndarray (no bytes move)."""
        return self.array

    def copy(self) -> np.ndarray:
        """An owned, writable copy — the only sanctioned way to mutate a
        fetched partition (records bytes_copied)."""
        return materialize(self.array)

    def __repr__(self) -> str:
        kind = "owned" if self.owned else "view"
        return (f"Buf({self.array.shape}, {self.array.dtype}, "
                f"{kind} from {self.source or '?'})")
