"""Compute-Data-Manager: data-aware, late-binding CU scheduling over pilots.

Paper §3.3 / Fig. 5: "The Compute-Data-Manager will assign submitted
Compute-Units and Data-Units to a Pilot taking into account the current
available Pilots, their utilization and data locality."

TPU adaptation of locality: the expensive boundaries are host<->HBM staging
and cross-slice transfers, so the score prefers (1) the pilot whose DEVICE
tier already holds the CU's DataUnits, then (2) matching affinity labels,
then (3) host-resident data, then (4) checkpoint-tier residency (a spilled
partition restores from the pilot's durable node-local store, still
beating a refetch from the home placement), then (5) any-tier replica
stickiness, then (6) lowest queue depth. Late binding: CUs wait in the
manager queue until some pilot is provisioned and healthy.

Multi-pilot locality: when a DataUnit is bound to a PilotDataService,
residency is *per pilot* — each pilot is scored by the fraction of the
DU's partitions ITS OWN TierManager measurably holds (replicas demoted
inside the pilot stop earning device credit; pilots outside the data
service earn none), so the CU lands on the pilot actually holding the
majority of its data.  On binding, the manager queues pre-binding
stage-in: the partitions the CU declared it reads first are replicated
toward the CHOSEN pilot's tiers, and the pilot waits for those copies to
land before the CU body runs (paper's ensure-availability semantics).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

from repro.core.backends.base import get_backend
from repro.core.data import DataUnit
from repro.core.pilot import (ComputeUnit, ComputeUnitDescription,
                              PilotCompute, PilotComputeDescription, State)

# locality score weights (device residency dominates, as HBM>host>disk;
# W_CKPT ranks checkpoint-tier residency below host but above absent — a
# pilot that spilled a partition to its durable tier restores it from
# node-local disk, which still beats refetching from the home store; and
# W_LOCAL rewards any-tier replica stickiness so a pilot whose replica was
# demoted under pressure still beats one that must refetch everything)
W_DEVICE, W_AFFINITY, W_HOST, W_CKPT, W_LOCAL, W_QUEUE = (
    100.0, 10.0, 5.0, 3.0, 2.0, 1.0)


class PilotComputeService:
    """Provision/release pilots across backend adaptors (paper's PCS)."""

    def __init__(self):
        self.pilots: Dict[str, PilotCompute] = {}
        self._lock = threading.Lock()

    def submit_pilot(self, desc: PilotComputeDescription) -> PilotCompute:
        backend = get_backend(desc.backend)
        pilot = backend.provision(desc)
        with self._lock:
            self.pilots[pilot.id] = pilot
        return pilot

    def release(self, pilot: PilotCompute):
        backend = get_backend(pilot.desc.backend)
        backend.release(pilot)
        with self._lock:
            self.pilots.pop(pilot.id, None)

    def cancel_all(self):
        for p in list(self.pilots.values()):
            self.release(p)

    def healthy_pilots(self) -> List[PilotCompute]:
        with self._lock:
            return [p for p in self.pilots.values()
                    if p.state == State.RUNNING]


class ComputeDataManager:
    """Late-binding scheduler: scores (pilot x CU) by data locality."""

    def __init__(self, service: PilotComputeService):
        self.service = service
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    @staticmethod
    def _per_pilot_du(pilot: PilotCompute, du: DataUnit):
        """The DU's PilotDataService when this (pilot, du) pair is scored
        per-pilot: the DU must be service-bound and the pilot must be a
        registered replica holder candidate."""
        pds = getattr(du, "pilot_data_service", None)
        if (pds is not None and getattr(pilot, "tier_manager", None)
                is not None and pds.knows(pilot.id)):
            return pds
        return None

    def _device_tier_hits(self, pilot: PilotCompute,
                          dus: Sequence[DataUnit]) -> float:
        """Fraction of each (single-manager) DU's partitions actually
        resident on the pilot's devices. With a TierManager the *measured*
        residency is used (a DU whose nominal tier is 'device' but whose
        partitions were demoted under memory pressure earns no device
        credit); without one we fall back to the DU's single tier field."""
        hits = 0.0
        for du in dus:
            frac = du.resident_fraction("device")
            if frac <= 0.0:
                continue
            tm = getattr(du, "tier_manager", None)
            be = (tm.backends if tm is not None else du.backends).get("device")
            mesh = getattr(be, "mesh", None)
            if mesh is None or pilot.mesh is None:
                hits += frac  # device-resident, single address space
            else:
                pilot_devs = {d.id for d in pilot.mesh.devices.flat}
                du_devs = {d.id for d in mesh.devices.flat}
                if du_devs & pilot_devs:
                    hits += frac
        return hits

    def score(self, pilot: PilotCompute, cu_desc: ComputeUnitDescription) -> float:
        s = 0.0
        shared_dus = []     # DUs scored by global (single-manager) residency
        for du in cu_desc.input_data:
            pds = self._per_pilot_du(pilot, du)
            if pds is not None:
                # per-pilot replica residency: one registry scan yields the
                # device, host, and any-tier-stickiness terms together
                n = du.num_partitions
                if n:
                    res = pds.residency(du, pilot.id)
                    s += W_DEVICE * res.get("device", 0) / n
                    s += W_HOST * res.get("host", 0) / n
                    s += W_CKPT * res.get("checkpoint", 0) / n
                    s += W_LOCAL * sum(res.values()) / n
            elif getattr(du, "pilot_data_service", None) is None:
                shared_dus.append(du)
            # else: replica-managed DU on a pilot outside the data
            # service — it holds nothing, so no locality credit
        s += W_DEVICE * self._device_tier_hits(pilot, shared_dus)
        for du in shared_dus:
            n = du.num_partitions
            if n:
                res = du.residency()    # one scan for both colder terms
                s += W_HOST * res.get("host", 0) / n
                s += W_CKPT * res.get("checkpoint", 0) / n
        if cu_desc.affinity and cu_desc.affinity == pilot.desc.affinity:
            s += W_AFFINITY
        s -= W_QUEUE * pilot.utilization
        return s

    def select_pilot(self, cu_desc: ComputeUnitDescription,
                     timeout: float = 30.0,
                     exclude: frozenset = frozenset()) -> PilotCompute:
        t0 = time.time()
        while True:
            pilots = [p for p in self.service.healthy_pilots()
                      if p.id not in exclude]
            if pilots:
                return max(pilots, key=lambda p: self.score(p, cu_desc))
            if time.time() - t0 > timeout:
                raise TimeoutError("no healthy pilot available (late binding "
                                   "timed out)")
            time.sleep(0.01)

    def _prefetch_inputs(self, pilot: PilotCompute,
                         cu_desc: ComputeUnitDescription) -> List[Future]:
        """Paper's ensure-availability semantics: once a CU is bound to a
        pilot, start staging the partitions it declared it will read first
        (`prefetch_parts`) toward the CHOSEN pilot's tiers so stage-in
        overlaps the queue wait (async, refusable under budget pressure —
        never blocks submission). The returned futures become the CU's
        pre-binding barrier: the pilot waits for them to land before the
        CU body runs. No hint, no blind prefetch: staging partitions the
        CU never touches would evict ones it is about to read."""
        tm = getattr(pilot, "tier_manager", None)
        if tm is None or not cu_desc.prefetch_parts or not cu_desc.input_data:
            return []
        # the indices are partition positions of the primary (first) DU;
        # applying them to sibling DUs would stage partitions the CU never
        # touches and evict ones it is about to read
        du = cu_desc.input_data[0]
        futs: List[Future] = []
        pds = getattr(du, "pilot_data_service", None)
        if pds is not None and pds.knows(pilot.id):
            # distributed Pilot-Data: replicate toward the chosen pilot's
            # own managed tiers (true pre-binding stage-in)
            for i in cu_desc.prefetch_parts:
                if 0 <= i < du.num_partitions:
                    futs.append(pds.replicate_async(du, i, pilot.id))
        elif getattr(du, "tier_manager", None) is tm:
            tier = "device" if du.tier == "device" else "host"
            for i in cu_desc.prefetch_parts:
                f = du.prefetch(i, tier)
                if f is not None:
                    futs.append(f)
        return futs

    # ------------------------------------------------------------------
    def submit(self, cu_desc: ComputeUnitDescription,
               exclude: frozenset = frozenset(),
               pilot: Optional[PilotCompute] = None) -> ComputeUnit:
        """Late-bind `cu_desc` onto the best-scoring pilot (or onto an
        explicitly chosen `pilot`, e.g. a replica-aware map_reduce group)
        and queue its pre-binding stage-in."""
        cu = ComputeUnit(cu_desc)
        if pilot is None:
            pilot = self.select_pilot(cu_desc, exclude=exclude)
        self.history.append({"cu": cu.id, "pilot": pilot.id,
                             "score": self.score(pilot, cu_desc),
                             "t": time.time()})
        cu.prebind_futures = self._prefetch_inputs(pilot, cu_desc)
        pilot.submit_cu(cu)
        return cu

    def run(self, fn, *args, input_data=(), affinity: str = "", **kwargs):
        """Convenience: submit and return the CU."""
        return self.submit(ComputeUnitDescription(
            fn=fn, args=args, kwargs=kwargs, input_data=input_data,
            affinity=affinity))

    def result_with_retry(self, cu_desc: ComputeUnitDescription,
                          retries: int = 2,
                          timeout: Optional[float] = None):
        """Run a CU to completion, transparently resubmitting on CU/pilot
        failure (task-level fault tolerance; pilot-level recovery lives in
        repro.runtime.fault_tolerance). Each retry re-runs late binding
        with every pilot that already failed this CU *excluded*, so a
        retry cannot late-bind straight back onto the pilot that just
        failed; when every healthy pilot has failed it, the exclusion
        resets rather than stranding the CU."""
        last: Optional[Exception] = None
        exclude: set = set()
        for _ in range(retries + 1):
            healthy = {p.id for p in self.service.healthy_pilots()}
            if healthy and healthy <= exclude:
                exclude.clear()
            cu = self.submit(cu_desc, exclude=frozenset(exclude))
            try:
                return cu.future.result(timeout)
            except Exception as e:  # noqa: BLE001
                last = e
                if cu.pilot_id:
                    exclude.add(cu.pilot_id)
        raise last
