"""Compute-Data-Manager: data-aware, late-binding CU scheduling over pilots.

Paper §3.3 / Fig. 5: "The Compute-Data-Manager will assign submitted
Compute-Units and Data-Units to a Pilot taking into account the current
available Pilots, their utilization and data locality."

TPU adaptation of locality: the expensive boundaries are host<->HBM staging
and cross-slice transfers, so the score prefers (1) the pilot whose DEVICE
tier already holds the CU's DataUnits, then (2) matching affinity labels,
then (3) host-resident data, then (4) lowest queue depth. Late binding: CUs
wait in the manager queue until some pilot is provisioned and healthy.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.core.backends.base import get_backend
from repro.core.data import DataUnit
from repro.core.pilot import (ComputeUnit, ComputeUnitDescription,
                              PilotCompute, PilotComputeDescription, State)

# locality score weights (device residency dominates, as HBM>host>disk)
W_DEVICE, W_AFFINITY, W_HOST, W_QUEUE = 100.0, 10.0, 5.0, 1.0


class PilotComputeService:
    """Provision/release pilots across backend adaptors (paper's PCS)."""

    def __init__(self):
        self.pilots: Dict[str, PilotCompute] = {}
        self._lock = threading.Lock()

    def submit_pilot(self, desc: PilotComputeDescription) -> PilotCompute:
        backend = get_backend(desc.backend)
        pilot = backend.provision(desc)
        with self._lock:
            self.pilots[pilot.id] = pilot
        return pilot

    def release(self, pilot: PilotCompute):
        backend = get_backend(pilot.desc.backend)
        backend.release(pilot)
        with self._lock:
            self.pilots.pop(pilot.id, None)

    def cancel_all(self):
        for p in list(self.pilots.values()):
            self.release(p)

    def healthy_pilots(self) -> List[PilotCompute]:
        with self._lock:
            return [p for p in self.pilots.values()
                    if p.state == State.RUNNING]


class ComputeDataManager:
    """Late-binding scheduler: scores (pilot x CU) by data locality."""

    def __init__(self, service: PilotComputeService):
        self.service = service
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def _device_tier_hits(self, pilot: PilotCompute,
                          dus: Sequence[DataUnit]) -> float:
        """Fraction of each DU's partitions actually resident on the pilot's
        devices. With a TierManager the *measured* residency is used (a DU
        whose nominal tier is 'device' but whose partitions were demoted
        under memory pressure earns no device credit); without one we fall
        back to the DU's single tier field."""
        hits = 0.0
        for du in dus:
            frac = du.resident_fraction("device")
            if frac <= 0.0:
                continue
            tm = getattr(du, "tier_manager", None)
            be = (tm.backends if tm is not None else du.backends).get("device")
            mesh = getattr(be, "mesh", None)
            if mesh is None or pilot.mesh is None:
                hits += frac  # device-resident, single address space
            else:
                pilot_devs = {d.id for d in pilot.mesh.devices.flat}
                du_devs = {d.id for d in mesh.devices.flat}
                if du_devs & pilot_devs:
                    hits += frac
        return hits

    def score(self, pilot: PilotCompute, cu_desc: ComputeUnitDescription) -> float:
        dus = list(cu_desc.input_data)
        s = W_DEVICE * self._device_tier_hits(pilot, dus)
        if cu_desc.affinity and cu_desc.affinity == pilot.desc.affinity:
            s += W_AFFINITY
        s += W_HOST * sum(du.resident_fraction("host") for du in dus)
        s -= W_QUEUE * pilot.utilization
        return s

    def select_pilot(self, cu_desc: ComputeUnitDescription,
                     timeout: float = 30.0,
                     exclude: frozenset = frozenset()) -> PilotCompute:
        t0 = time.time()
        while True:
            pilots = [p for p in self.service.healthy_pilots()
                      if p.id not in exclude]
            if pilots:
                return max(pilots, key=lambda p: self.score(p, cu_desc))
            if time.time() - t0 > timeout:
                raise TimeoutError("no healthy pilot available (late binding "
                                   "timed out)")
            time.sleep(0.01)

    def _prefetch_inputs(self, pilot: PilotCompute,
                         cu_desc: ComputeUnitDescription) -> None:
        """Paper's ensure-availability semantics: once a CU is bound to a
        pilot, start staging the partitions it declared it will read first
        (`prefetch_parts`) toward the pilot's tiers so stage-in overlaps
        the queue wait (async, refusable under budget pressure — never
        blocks submission). No hint, no blind prefetch: staging partitions
        the CU never touches would evict ones it is about to read."""
        tm = getattr(pilot, "tier_manager", None)
        if tm is None or not cu_desc.prefetch_parts or not cu_desc.input_data:
            return
        # the indices are partition positions of the primary (first) DU;
        # applying them to sibling DUs would stage partitions the CU never
        # touches and evict ones it is about to read
        du = cu_desc.input_data[0]
        if getattr(du, "tier_manager", None) is tm:
            tier = "device" if du.tier == "device" else "host"
            for i in cu_desc.prefetch_parts:
                du.prefetch(i, tier)

    # ------------------------------------------------------------------
    def submit(self, cu_desc: ComputeUnitDescription,
               exclude: frozenset = frozenset()) -> ComputeUnit:
        cu = ComputeUnit(cu_desc)
        pilot = self.select_pilot(cu_desc, exclude=exclude)
        self.history.append({"cu": cu.id, "pilot": pilot.id,
                             "score": self.score(pilot, cu_desc),
                             "t": time.time()})
        self._prefetch_inputs(pilot, cu_desc)
        pilot.submit_cu(cu)
        return cu

    def run(self, fn, *args, input_data=(), affinity: str = "", **kwargs):
        """Convenience: submit and return the CU."""
        return self.submit(ComputeUnitDescription(
            fn=fn, args=args, kwargs=kwargs, input_data=input_data,
            affinity=affinity))

    def result_with_retry(self, cu_desc: ComputeUnitDescription,
                          retries: int = 2,
                          timeout: Optional[float] = None):
        """Run a CU to completion, transparently resubmitting on CU/pilot
        failure (task-level fault tolerance; pilot-level recovery lives in
        repro.runtime.fault_tolerance). Each retry re-runs late binding, so a
        CU whose pilot died lands on a surviving pilot."""
        last: Optional[Exception] = None
        for _ in range(retries + 1):
            cu = self.submit(cu_desc)
            try:
                return cu.future.result(timeout)
            except Exception as e:  # noqa: BLE001
                last = e
        raise last
