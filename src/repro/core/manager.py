"""Compute-Data-Manager: data-aware, late-binding CU scheduling over pilots.

Paper §3.3 / Fig. 5: "The Compute-Data-Manager will assign submitted
Compute-Units and Data-Units to a Pilot taking into account the current
available Pilots, their utilization and data locality."

TPU adaptation of locality: the expensive boundaries are host<->HBM staging
and cross-slice transfers, so the score prefers (1) the pilot whose DEVICE
tier already holds the CU's DataUnits, then (2) matching affinity labels,
then (3) host-resident data, then (4) checkpoint-tier residency (a spilled
partition restores from the pilot's durable node-local store, still
beating a refetch from the home placement), then (5) any-tier replica
stickiness, then (6) lowest queue depth. Late binding: CUs wait in the
manager queue until some pilot is provisioned and healthy.

Multi-pilot locality: when a DataUnit is bound to a PilotDataService,
residency is *per pilot* — each pilot is scored by the fraction of the
DU's partitions ITS OWN TierManager measurably holds (replicas demoted
inside the pilot stop earning device credit; pilots outside the data
service earn none), so the CU lands on the pilot actually holding the
majority of its data.  On binding, the manager queues pre-binding
stage-in: the partitions the CU declared it reads first are replicated
toward the CHOSEN pilot's tiers, and the pilot waits for those copies to
land before the CU body runs (paper's ensure-availability semantics).

Since PR 5 the scoring itself is a pluggable strategy
(repro.core.scheduling): ComputeDataManager drives a SchedulingPolicy,
whose default LocalityPolicy reproduces the scoring described above
bit-for-bit; the W_* constants re-exported here live with the policy.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

from repro.core.backends.base import get_backend
from repro.core.pilot import (ComputeUnit, ComputeUnitDescription,
                              PilotCompute, PilotComputeDescription, State)
# the locality score weights live with the policies now; re-exported here
# because four PRs of code and tests import them from manager
from repro.core.scheduling import (LocalityPolicy, SchedulingPolicy,  # noqa: F401
                                   W_AFFINITY, W_CKPT, W_DEVICE, W_HOST,
                                   W_LOCAL, W_QUEUE)
from repro.core.supervisor import POLL_BACKOFF, RETRY_BACKOFF


class PilotComputeService:
    """Provision/release pilots across backend adaptors (paper's PCS)."""

    def __init__(self):
        self.pilots: Dict[str, PilotCompute] = {}
        self._lock = threading.Lock()

    def submit_pilot(self, desc: PilotComputeDescription) -> PilotCompute:
        backend = get_backend(desc.backend)
        pilot = backend.provision(desc)
        with self._lock:
            self.pilots[pilot.id] = pilot
        return pilot

    def release(self, pilot: PilotCompute):
        backend = get_backend(pilot.desc.backend)
        backend.release(pilot)
        with self._lock:
            self.pilots.pop(pilot.id, None)

    def cancel_all(self):
        for p in list(self.pilots.values()):
            self.release(p)

    def healthy_pilots(self) -> List[PilotCompute]:
        with self._lock:
            return [p for p in self.pilots.values()
                    if p.state == State.RUNNING]


class ComputeDataManager:
    """Late-binding scheduler: scores (pilot x CU) through a pluggable
    SchedulingPolicy (default LocalityPolicy — the historical W_* data-
    locality scoring, now a strategy in repro.core.scheduling).

    `history` keeps the most recent `history_limit` placement decisions
    (a bounded window — long-running sessions serving millions of CUs
    must not grow driver memory without limit); `stats()` summarizes the
    whole lifetime regardless of the window.
    """

    _STAT_SHARDS = 8

    def __init__(self, service: PilotComputeService,
                 policy: Optional[SchedulingPolicy] = None,
                 history_limit: int = 1024):
        self.service = service
        self.policy: SchedulingPolicy = policy or LocalityPolicy()
        self.history_limit = max(1, int(history_limit))
        self.history: List[dict] = []   # bounded: see _record
        # stats locks are sharded BY PILOT (hash(pilot.id) -> shard), the
        # same move PR 2 made for read accounting: batched submissions
        # against different pilots account concurrently instead of
        # serializing on one manager-wide lock.  A pilot always maps to
        # the same shard, so its per-pilot counter stays exact; the
        # lifetime total is the sum of per-shard counters.
        n = self._STAT_SHARDS
        self._stats_locks = [threading.Lock() for _ in range(n)]
        self._submitted_shards = [0] * n
        self._per_pilot_shards: List[Dict[str, int]] = [{} for _ in range(n)]
        self._engine = None             # lazy TaskEngine (see .engine)
        self._engine_lock = threading.Lock()

    # ------------------------------------------------------------------
    def score(self, pilot: PilotCompute,
              cu_desc: ComputeUnitDescription) -> float:
        """Policy delegation (kept as a method: four PRs of tests and
        benchmarks call manager.score directly)."""
        return self.policy.score(pilot, cu_desc)

    def eligible_pilots(self, exclude: frozenset = frozenset()
                        ) -> List[PilotCompute]:
        """Healthy, non-excluded, non-quarantined pilots — the one filter
        every placement path shares.  Quarantine (supervisor suspicion)
        fails closed: an empty result makes late binding WAIT, it never
        falls back onto a suspect pilot."""
        pilots = [p for p in self.service.healthy_pilots()
                  if p.id not in exclude]
        return self.policy.eligible(pilots)

    def _select_scored(self, cu_desc: ComputeUnitDescription,
                       timeout: float = 30.0,
                       exclude: frozenset = frozenset()
                       ) -> Tuple[PilotCompute, float]:
        """Late binding: wait for an eligible pilot, return the best-
        scoring one AND its score, so the submit path records the decision
        without scoring the winner a second time (scoring scans every
        input DU's partitions — the recompute scaled with pilots x DUs x
        parts).  The wait uses a monotonic deadline (wall-clock jumps
        can't expire it early) and jittered backoff (a fleet of blocked
        submitters doesn't stampede the registry in lockstep)."""
        deadline = time.monotonic() + timeout
        attempt = 0
        while True:
            pilots = self.eligible_pilots(exclude)
            if pilots:
                return self.policy.select(pilots, cu_desc)
            if time.monotonic() > deadline:
                raise TimeoutError("no eligible pilot available (late "
                                   "binding timed out)")
            POLL_BACKOFF.sleep(attempt)
            attempt += 1

    def select_pilot(self, cu_desc: ComputeUnitDescription,
                     timeout: float = 30.0,
                     exclude: frozenset = frozenset()) -> PilotCompute:
        return self._select_scored(cu_desc, timeout, exclude)[0]

    # ------------------------------------------------------------------
    def _shard(self, pilot_id: str) -> int:
        return hash(pilot_id) % self._STAT_SHARDS

    def _record(self, cu: ComputeUnit, pilot: PilotCompute,
                score: float) -> None:
        """Append one placement decision, keeping `history` bounded and
        the lifetime counters exact."""
        self.history.append({"cu": cu.id, "pilot": pilot.id,
                             "score": score, "t": time.monotonic()})
        overflow = len(self.history) - self.history_limit
        if overflow > 0:
            del self.history[:overflow]
        shard = self._shard(pilot.id)
        with self._stats_locks[shard]:
            self._submitted_shards[shard] += 1
            pp = self._per_pilot_shards[shard]
            pp[pilot.id] = pp.get(pilot.id, 0) + 1

    def record_batch(self, pilot: PilotCompute, tasks, score: float) -> None:
        """Account a whole engine batch bound to one pilot under that
        pilot's stats shard: ONE lock pass and ONE counter update for N
        tasks.  History gets per-task entries only up to the bounded
        window (appending 10^5 dicts that the very next trim would drop
        is pure hot-path waste), so small batches — e.g. the legacy
        map_reduce path's one-CU-per-partition submissions — keep their
        familiar one-entry-per-task history shape."""
        n = len(tasks)
        if n == 0:
            return
        now = time.monotonic()
        window = tasks if n <= self.history_limit \
            else tasks[n - self.history_limit:]
        pid = pilot.id
        append = self.history.append
        for t in window:
            name = getattr(t.desc, "name", "") if t.desc is not None else ""
            append({"cu": name or "fn-task", "pilot": pid,
                    "score": score, "t": now})
        overflow = len(self.history) - self.history_limit
        if overflow > 0:
            del self.history[:overflow]
        shard = self._shard(pid)
        with self._stats_locks[shard]:
            self._submitted_shards[shard] += n
            pp = self._per_pilot_shards[shard]
            pp[pid] = pp.get(pid, 0) + n

    def stats(self) -> dict:
        """Lifetime scheduling summary (exact even after the bounded
        `history` window has rolled over): per-shard counters summed
        under their own locks."""
        submitted = 0
        per_pilot: Dict[str, int] = {}
        for i, lock in enumerate(self._stats_locks):
            with lock:
                submitted += self._submitted_shards[i]
                per_pilot.update(self._per_pilot_shards[i])
        return {"policy": self.policy.name, "submitted": submitted,
                "per_pilot": per_pilot,
                "history_len": len(self.history),
                "history_limit": self.history_limit}

    def _prefetch_inputs(self, pilot: PilotCompute,
                         cu_desc: ComputeUnitDescription) -> List[Future]:
        """Paper's ensure-availability semantics: once a CU is bound to a
        pilot, start staging the partitions it declared it will read first
        (`prefetch_parts`) toward the CHOSEN pilot's tiers so stage-in
        overlaps the queue wait (async, refusable under budget pressure —
        never blocks submission). The returned futures become the CU's
        pre-binding barrier: the pilot waits for them to land before the
        CU body runs. No hint, no blind prefetch: staging partitions the
        CU never touches would evict ones it is about to read."""
        tm = getattr(pilot, "tier_manager", None)
        if tm is None or not cu_desc.prefetch_parts or not cu_desc.input_data:
            return []
        # the indices are partition positions of the primary (first) DU;
        # applying them to sibling DUs would stage partitions the CU never
        # touches and evict ones it is about to read
        du = cu_desc.input_data[0]
        futs: List[Future] = []
        pds = getattr(du, "pilot_data_service", None)
        if pds is not None and pds.knows(pilot.id):
            # distributed Pilot-Data: replicate toward the chosen pilot's
            # own managed tiers (true pre-binding stage-in)
            for i in cu_desc.prefetch_parts:
                if 0 <= i < du.num_partitions:
                    futs.append(pds.replicate_async(du, i, pilot.id))
        elif getattr(du, "tier_manager", None) is tm:
            tier = "device" if du.tier == "device" else "host"
            for i in cu_desc.prefetch_parts:
                f = du.prefetch(i, tier)
                if f is not None:
                    futs.append(f)
        return futs

    # ------------------------------------------------------------------
    def submit(self, cu_desc: ComputeUnitDescription,
               exclude: frozenset = frozenset(),
               pilot: Optional[PilotCompute] = None) -> ComputeUnit:
        """Late-bind `cu_desc` onto the best-scoring pilot (or onto an
        explicitly chosen `pilot`, e.g. a replica-aware map_reduce group)
        and queue its pre-binding stage-in."""
        cu = ComputeUnit(cu_desc)
        if pilot is None:
            # the winning score is threaded through from selection — the
            # old recompute here doubled the hot-path scoring cost
            pilot, score = self._select_scored(cu_desc, exclude=exclude)
        else:
            score = self.policy.score(pilot, cu_desc)
        self._record(cu, pilot, score)
        cu.prebind_futures = self._prefetch_inputs(pilot, cu_desc)
        pilot.submit_cu(cu)
        return cu

    def run(self, fn, *args, input_data=(), affinity: str = "", **kwargs):
        """Convenience: submit and return the CU."""
        return self.submit(ComputeUnitDescription(
            fn=fn, args=args, kwargs=kwargs, input_data=input_data,
            affinity=affinity))

    # ------------------------------------------------------------------
    @property
    def engine(self):
        """The manager's high-throughput task engine (lazy: sessions that
        never call submit_tasks pay nothing for it)."""
        eng = self._engine
        if eng is None:
            with self._engine_lock:
                eng = self._engine
                if eng is None:
                    from repro.core.taskengine import TaskEngine
                    eng = self._engine = TaskEngine(self)
        return eng

    def submit_tasks(self, items, *, retries: int = 0,
                     timeout: float = 30.0):
        """Batched function-as-task dispatch (the raptor-style engine):
        the whole batch is scored in one policy pass and fed to the
        chosen pilots' resident worker pools under backpressure.  Items
        may be bare callables, ``(fn, args[, kwargs])`` tuples, or
        ``ComputeUnitDescription``s; returns a ``TaskBatch`` of result
        futures in submit order.  ``submit`` remains the single-CU path
        with full CU semantics (pre-binding stage-in, mesh context,
        per-CU Future)."""
        return self.engine.submit_tasks(items, retries=retries,
                                        timeout=timeout)

    def result_with_retry(self, cu_desc: ComputeUnitDescription,
                          retries: int = 2,
                          timeout: Optional[float] = None):
        """Run a CU to completion, transparently resubmitting on CU/pilot
        failure (task-level fault tolerance; pilot-level recovery lives in
        the supervisor — repro.core.supervisor). Each retry re-runs late
        binding with every pilot that already failed this CU *excluded*,
        so a retry cannot late-bind straight back onto the pilot that just
        failed; when every healthy pilot has failed it, the exclusion
        resets rather than stranding the CU.  Retries back off with
        bounded exponential + jitter (immediate resubmission against a
        fleet that just lost a node only amplifies the failure)."""
        last: Optional[Exception] = None
        exclude: set = set()
        for attempt in range(retries + 1):
            if attempt > 0:
                RETRY_BACKOFF.sleep(attempt - 1)
            healthy = {p.id for p in self.service.healthy_pilots()}
            if healthy and healthy <= exclude:
                exclude.clear()
            cu = self.submit(cu_desc, exclude=frozenset(exclude))
            try:
                return cu.future.result(timeout)
            except Exception as e:  # noqa: BLE001
                last = e
                if cu.pilot_id:
                    exclude.add(cu.pilot_id)
        raise last
