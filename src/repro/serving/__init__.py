"""LM serving on the pilot substrate (see repro.serving.engine)."""
from repro.serving.engine import (ServeRequest, ServingEngine,
                                  sample_tokens, splice_row)

__all__ = ["ServingEngine", "ServeRequest", "sample_tokens", "splice_row"]
