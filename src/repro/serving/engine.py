"""ServingEngine: continuous-batching LM serving ON the pilot substrate.

The ROADMAP's top open item — and the paper's whole argument — is that
retained resources (compute AND memory) are the right home for
data-intensive work.  The old ``launch/serve.py`` driver ran *beside*
the pilot system: it held params and KV state in loop locals, routed
nothing through the scheduler, and lost every in-flight request when a
pilot died.  This module is the join:

  * **model shards are tiered Pilot-Data partitions** — the flattened
    param leaves become one DataUnit (``<name>.shards``) registered with
    ``persist=True`` (durable checkpoint home) and a replication target,
    replicated *pinned* into every serving pilot's managed tiers.  Each
    pilot reconstructs its params from its own replica through the PR-8
    zero-copy read path (``taskengine.read_partition`` → mmap/aliasing
    views) and retains them in the pilot's ``jit_cached`` executable
    cache — the paper's retain-and-reuse applied to weights;
  * **KV-cache pages are durable partitions** — each request's
    recoverable decode state (prompt + generated-so-far) is an
    appended partition of ``<name>.kv``, rewritten at page granularity
    (``page_tokens``) and written through to the durable tier, so the
    sequence needed to rebuild a KV cache survives the pilot that held
    the device-tier cache;
  * **requests route replica-aware** — dispatch goes through the
    session's ``SchedulingPolicy``: each request is scored as a CU whose
    ``input_data`` is the shards DU, so pilots holding shard replicas
    win, quarantined pilots are excluded fail-closed, and placements
    land in the scheduler's history/stats like any other work;
  * **decode loops are long-lived tasks** — each replica's continuous-
    batching loop runs on a resident task (``TaskEngine.submit_resident``)
    pinned to its pilot, so ``current_pilot()`` resolves inside the loop
    and shard reads hit that pilot's tiers;
  * **pilot loss mid-stream recovers from the durable tier** — under a
    supervising session (PR 7) a killed pilot is quarantined/respawned;
    this engine's reaper re-reads each in-flight request's KV pages from
    the home/checkpoint tier, re-prefills the recovered sequence on a
    surviving replica, and decoding continues for exactly the remaining
    tokens.  Greedy decoding makes the replayed tail deterministic;
    either way every request completes with its exact token count.

The continuous-batching loop here also fixes the two serve.py bugs:
finished rows ARE refilled (a pending prompt is dequeued, prefilled as a
batch-of-1 and spliced into the freed row of the batched cache), and
retired/padded rows are masked out of both sampling and the throughput
accounting (``tokens_served`` counts active rows only).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from types import SimpleNamespace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pilot import ComputeUnitDescription, State
from repro.core.taskengine import read_partition


# ---------------------------------------------------------------------------
# pure helpers (shared with the isolated-stack baseline in bench_serving)
# ---------------------------------------------------------------------------
def _batch_axis(dst_shape, src_shape) -> int:
    """The axis where a batched cache leaf and a batch-of-1 prefill leaf
    disagree — i.e. the batch axis, found structurally so every cache
    family works (``(L,B,S,...)`` dict stacks batch on axis 1, the
    parallel_ssm tuple layout on axis 0) without a per-model table."""
    for ax, (d, s) in enumerate(zip(dst_shape, src_shape)):
        if d != s:
            return ax
    return 0    # shapes equal: batch size 1 replacing row 0


def splice_row(cache, row_cache, row: int):
    """Continuous-batching refill: write a batch-of-1 prefill cache into
    row `row` of the batched cache (every leaf, at its own batch axis).
    This is the piece the old serve.py loop was missing — it reset
    ``positions`` but never installed a new prompt's KV state."""
    def _one(dst, src):
        ax = _batch_axis(dst.shape, src.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), row, axis=ax)
    return jax.tree.map(_one, cache, row_cache)


def sample_tokens(logits, active, key, temperature: float):
    """Next-token sampling with inactive rows masked out: retired and
    padded rows still occupy the batch (shapes stay static for the jitted
    decode), but their sampled token is forced to 0 so they never leak
    into outputs — and callers count only ``active`` rows as served."""
    if temperature > 0:
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits / temperature, -1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    return jnp.where(active, tok, 0).astype(jnp.int32), key


# ---------------------------------------------------------------------------
class ServeRequest:
    """One in-flight generation request and its result future.

    ``rid`` is the request's partition index in the engine's KV-page
    DataUnit; ``ctx`` is the sequence to prefill when (re)entering a
    batch row — the prompt initially, the recovered prompt+generated
    pages after a failover; ``prior`` is the recovered generated prefix,
    so ``prior + fresh tokens == max_new_tokens`` exactly."""

    __slots__ = ("rid", "prompt", "max_new_tokens", "ctx", "prior",
                 "tokens", "error", "pilot_id", "recoveries",
                 "t_submit", "t_done", "_done")

    def __init__(self, rid: int, prompt: np.ndarray, max_new_tokens: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new_tokens = int(max_new_tokens)
        self.ctx = prompt
        self.prior: List[int] = []
        self.tokens: Optional[List[int]] = None
        self.error: Optional[BaseException] = None
        self.pilot_id: Optional[str] = None
        self.recoveries = 0
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    def result(self, timeout: Optional[float] = None) -> List[int]:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done after "
                               f"{timeout}s")
        if self.error is not None:
            raise self.error
        return list(self.tokens or [])

    def _finish(self, tokens: List[int]) -> None:
        self.tokens = tokens
        self.t_done = time.perf_counter()
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self.error = exc
        self.t_done = time.perf_counter()
        self._done.set()

    def __repr__(self) -> str:
        state = ("done" if self.done and self.error is None
                 else "error" if self.done else "pending")
        return f"ServeRequest(rid={self.rid}, n={self.max_new_tokens}, " \
               f"{state})"


class _Replica:
    """One serving pilot's routed-request queue + resident-loop handle."""

    def __init__(self, pilot):
        self.pilot = pilot
        self.queue: deque = deque()
        self.cond = threading.Condition()
        self.stop = threading.Event()
        self.task = None                      # resident taskengine.Task
        self.dead = False
        self.active: Dict[int, ServeRequest] = {}   # row -> request

    def push(self, req: ServeRequest) -> None:
        with self.cond:
            self.queue.append(req)
            self.cond.notify_all()

    def pop(self, timeout: float) -> Optional[ServeRequest]:
        with self.cond:
            if not self.queue and timeout > 0:
                self.cond.wait(timeout)
            return self.queue.popleft() if self.queue else None

    def wake(self) -> None:
        with self.cond:
            self.cond.notify_all()

    def drain(self) -> List[ServeRequest]:
        """Every request this replica still owes: queued + in rows.  Only
        called after the resident loop has exited (the reaper joins the
        task first), so the row map is quiescent."""
        with self.cond:
            out = list(self.queue)
            self.queue.clear()
        out.extend(self.active.values())
        self.active = {}
        return out


class _Runtime:
    """Per-pilot retained serving state (lives in pilot._jit_cache)."""

    def __init__(self, params, prefill, decode):
        self.params = params
        self.prefill = prefill
        self.decode = decode


# ---------------------------------------------------------------------------
class ServingEngine:
    """Continuous-batching LM serving on a PilotSession (module doc).

    Parameters
    ----------
    session: the PilotSession to serve on — its pilots (provisioned with
        ``memory_gb`` so they carry TierManagers) become serving
        replicas.  Pass ``supervise=True`` sessions for mid-stream
        pilot-loss recovery.
    model: a built model exposing ``prefill(params, batch, max_len)`` and
        ``decode(params, cache, tokens, positions)`` plus ``cfg`` (the
        contract of repro.models.model.Model; the tests drive the engine
        with a stub model through the same surface).
    params: the param pytree to shard (default: ``model.init(key(seed))``).
    batch_size: decode rows per replica (equal-batch comparisons against
        the isolated stack use the same number).
    page_tokens: KV-page flush granularity — a request's durable state is
        rewritten every `page_tokens` generated tokens (and at finish).
    replication: shard replication target (default ``min(2, n_pilots)``).
    """

    def __init__(self, session, model, *, params=None, name: str = "serve",
                 batch_size: int = 4, max_len: int = 256,
                 temperature: float = 0.0, page_tokens: int = 16,
                 replication: Optional[int] = None, seed: int = 0):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.session = session
        self.model = model
        self.cfg = model.cfg
        self.name = name
        self.batch_size = int(batch_size)
        self.max_len = int(max_len)
        self.temperature = float(temperature)
        self.page_tokens = max(1, int(page_tokens))
        self._replication = replication
        self._seed = seed
        self._params = params
        self.shards = None                    # DataUnit: model shard leaves
        self.kv = None                        # DataUnit: per-request pages
        self._treedef = None
        self._n_shards = 0
        self._replicas: Dict[str, _Replica] = {}
        self._unrouted: deque = deque()
        self._lock = threading.Lock()
        self._done_cond = threading.Condition(self._lock)
        self._requests: List[ServeRequest] = []
        self._completed = 0
        self._deployed = False
        self._closed = False
        self._reaper_stop = threading.Event()
        self._reaper: Optional[threading.Thread] = None
        self.counters = {"tokens_served": 0, "decode_steps": 0,
                         "refills": 0, "waves": 0, "recovered_requests": 0,
                         "replica_deaths": 0, "drained_replicas": 0}

    # -- deployment ------------------------------------------------------
    def deploy(self, reaper_interval_s: float = 0.05) -> "ServingEngine":
        """Shard the params into Pilot-Data, replicate them to every
        pilot, start a resident decode loop per replica and the failover
        reaper.  Idempotent."""
        if self._deployed:
            return self
        pilots = [p for p in self.session.pilots
                  if p.state is State.RUNNING]
        if not pilots:
            raise RuntimeError("ServingEngine.deploy: the session has no "
                               "running pilots")
        if self._params is None:
            self._params = self.model.init(jax.random.key(self._seed))
        leaves, self._treedef = jax.tree_util.tree_flatten(self._params)
        np_leaves = [np.asarray(x) for x in leaves]
        self._n_shards = len(np_leaves)
        pds = self.session.data_service
        durable = pds.checkpoint_store is not None
        repl = (self._replication if self._replication is not None
                else min(2, len(pilots)))
        self.shards = self.session.data_parts(
            f"{self.name}.shards", np_leaves, tier="host",
            persist=durable, replication=repl)
        self.kv = self.session.data_parts(
            f"{self.name}.kv", [], tier="host", persist=False)
        self._durable = durable
        self._deployed = True
        for p in pilots:
            self._attach_replica(p)
        self._reaper = threading.Thread(
            target=self._reaper_loop, args=(reaper_interval_s,),
            daemon=True, name=f"{self.name}-reaper")
        self._reaper.start()
        # the session's autoscaler reads load() from here and asks for
        # replica handoff before scaling a serving pilot in
        engines = getattr(self.session, "serving_engines", None)
        if engines is not None and self not in engines:
            engines.append(self)
        return self

    def _attach_replica(self, pilot) -> None:
        """Join one pilot to the serving fleet: shard replicas pinned
        into its tiers (best effort — a capacity-refused leaf is pulled
        through lazily on first read) and a resident decode loop spawned
        on the pilot's worker pool."""
        pds = self.session.data_service
        if pds.knows(pilot.id):
            pds.replicate_to_pilot(self.shards, pilot.id, tier="host",
                                   pin=True)
        rep = _Replica(pilot)
        rep.task = self.session.manager.engine.submit_resident(
            self._serve_loop, rep, pilot=pilot,
            name=f"{self.name}-decode")
        with self._lock:
            self._replicas[pilot.id] = rep

    # -- request intake / routing ---------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> ServeRequest:
        """Accept one request: its prompt becomes a durable KV-page
        partition, then it is routed replica-aware to a serving pilot."""
        if not self._deployed:
            raise RuntimeError("ServingEngine.submit before deploy()")
        if self._closed:
            raise RuntimeError("ServingEngine is closed")
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        rid = self.kv.append_partition(prompt)
        if self._durable:
            self.kv.persist(parts=[rid])
        req = ServeRequest(rid, prompt, max_new_tokens)
        with self._lock:
            self._requests.append(req)
        self._route(req)
        return req

    def _eligible_replicas(self) -> List[_Replica]:
        with self._lock:
            reps = [r for r in self._replicas.values()
                    if not r.dead and r.pilot.state is State.RUNNING]
        policy = self.session.manager.policy
        ok = {p.id for p in policy.eligible([r.pilot for r in reps])}
        return [r for r in reps if r.pilot.id in ok]

    def _route(self, req: ServeRequest) -> None:
        """Replica-aware dispatch: score the request as a CU reading the
        shards DU, so the policy credits pilots holding shard replicas
        (and the quarantine filter fails closed — with no eligible
        replica the request parks in the unrouted queue until the
        supervisor respawns one)."""
        reps = self._eligible_replicas()
        if not reps:
            with self._lock:
                self._unrouted.append(req)
            return
        desc = ComputeUnitDescription(
            fn=_noop, input_data=(self.shards,),
            name=f"{self.name}:req{req.rid}")
        pilot, score = self.session.manager.policy.select(
            [r.pilot for r in reps], desc)
        self.session.manager.record_batch(
            pilot, (SimpleNamespace(desc=desc),), score)
        req.pilot_id = pilot.id
        with self._lock:
            rep = self._replicas.get(pilot.id)
        if rep is None or rep.dead:
            with self._lock:
                self._unrouted.append(req)
            return
        rep.push(req)

    # -- per-pilot retained runtime --------------------------------------
    def _pilot_runtime(self, pilot) -> _Runtime:
        """The pilot's retained serving state: params reconstructed from
        its own shard replicas (zero-copy reads through the pilot's
        tiers; a respawned pilot pulls through from siblings or the
        checkpoint home) and the warm prefill/decode executables, all
        living in the pilot's jit cache so a second loop on the same
        pilot pays nothing."""
        def build():
            arrs = []
            for i in range(self._n_shards):
                view = read_partition(self.shards, i)
                arrs.append(jnp.asarray(view))
            params = jax.tree_util.tree_unflatten(self._treedef, arrs)
            mesh = getattr(pilot, "mesh", None)
            model, max_len = self.model, self.max_len
            if mesh is not None:
                from repro.parallel.sharding import (AxisRules,
                                                     sharding_context)
                rules = AxisRules()

                def pf(params, batch):
                    with sharding_context(mesh, rules):
                        return model.prefill(params, batch, max_len)

                def dec(params, cache, tokens, positions):
                    with sharding_context(mesh, rules):
                        return model.decode(params, cache, tokens,
                                            positions)
            else:
                def pf(params, batch):
                    return model.prefill(params, batch, max_len)

                def dec(params, cache, tokens, positions):
                    return model.decode(params, cache, tokens, positions)
            return _Runtime(params, jax.jit(pf),
                            jax.jit(dec, donate_argnums=(1,)))
        return pilot.jit_cached((self.name, "runtime"), build)

    def _prefill_batch(self, ctx_rows: np.ndarray) -> dict:
        b, _ = ctx_rows.shape
        batch = {"tokens": jnp.asarray(ctx_rows)}
        cfg = self.cfg
        if getattr(cfg, "vision_tokens", 0):
            batch["patch_embeds"] = jnp.zeros(
                (b, cfg.vision_tokens, cfg.vision_embed_dim), jnp.float32)
        if getattr(cfg, "encoder_layers", 0):
            batch["frames"] = jnp.zeros(
                (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        return batch

    # -- the continuous-batching loop ------------------------------------
    def _serve_loop(self, rep: _Replica) -> int:
        """One replica's decode loop (a long-lived resident task pinned
        to its pilot).  Returns the number of requests it completed; on
        pilot loss it returns early, leaving its queue + rows for the
        reaper's failover."""
        pilot = rep.pilot
        rt = self._pilot_runtime(pilot)
        B = self.batch_size
        vision = getattr(self.cfg, "vision_tokens", 0) or 0
        rows: List[Optional[ServeRequest]] = [None] * B
        row_gen = np.zeros(B, np.int64)       # tokens generated in-row
        row_out: List[List[int]] = [[] for _ in range(B)]
        positions = np.zeros(B, np.int32)
        cache = None
        logits = None
        key = jax.random.key(self._seed + 1)
        served = 0

        def fill_row(r: int, req: ServeRequest) -> None:
            nonlocal cache, logits
            with self._lock:
                self.counters["refills"] += 1
            row_logits, row_cache = rt.prefill(
                rt.params, self._prefill_batch(req.ctx[None, :]))
            cache = splice_row(cache, row_cache, r)
            logits = logits.at[r].set(row_logits[0])
            rows[r] = req
            rep.active[r] = req
            row_gen[r] = 0
            row_out[r] = []
            positions[r] = len(req.ctx) + vision - 1

        def fill_wave(reqs: List[ServeRequest]) -> None:
            """First fill only (cache is None): batched prefill of every
            same-length context, free rows padded with copies of the
            first — padded rows start INACTIVE (rows[r] is None), so the
            masking keeps them out of sampling and accounting."""
            nonlocal cache, logits
            with self._lock:
                self.counters["waves"] += 1
            ctxs = [q.ctx for q in reqs]
            pad = ctxs[0]
            while len(ctxs) < B:
                ctxs.append(pad)
            logits, cache = rt.prefill(
                rt.params, self._prefill_batch(np.stack(ctxs)))
            for r, req in enumerate(reqs):
                rows[r] = req
                rep.active[r] = req
                row_gen[r] = 0
                row_out[r] = []
                positions[r] = len(req.ctx) + vision - 1

        while True:
            if rep.stop.is_set():
                return served
            if pilot.state is not State.RUNNING:
                # node loss: abandon the rows — the reaper recovers every
                # owed request from the durable KV pages
                rep.dead = True
                with self._lock:
                    self.counters["replica_deaths"] += 1
                return served
            # -- refill freed rows (the missing piece of the old loop) --
            free = [r for r in range(B) if rows[r] is None]
            idle = all(q is None for q in rows)
            for r in free:
                req = rep.pop(timeout=0.02 if idle and r == free[0] else 0)
                if req is None:
                    break
                if cache is None:
                    wave = [req]
                    want = len(req.ctx)
                    while len(wave) < B:
                        nxt = rep.pop(timeout=0)
                        if nxt is None:
                            break
                        if len(nxt.ctx) != want:
                            rep.push(nxt)   # ragged ctx: spliced next pass
                            break
                        wave.append(nxt)
                    fill_wave(wave)
                    break
                fill_row(r, req)
                idle = False
            active = np.array([q is not None for q in rows])
            if not active.any():
                continue
            # -- sample (inactive rows masked), account, retire ----------
            tok, key = sample_tokens(logits, jnp.asarray(active), key,
                                     self.temperature)
            tok_np = np.asarray(tok)
            n_active = int(active.sum())
            with self._lock:
                self.counters["tokens_served"] += n_active
            for r in range(B):
                req = rows[r]
                if req is None:
                    continue
                row_out[r].append(int(tok_np[r]))
                row_gen[r] += 1
                remaining = req.max_new_tokens - len(req.prior)
                finished = row_gen[r] >= remaining
                if finished or row_gen[r] % self.page_tokens == 0:
                    self._flush_pages(req, row_out[r])
                if finished:
                    self._complete(req, list(req.prior) + row_out[r])
                    rows[r] = None
                    rep.active.pop(r, None)
                    served += 1
            still = np.array([q is not None for q in rows])
            if still.any():
                positions[still] += 1
                logits, cache = rt.decode(rt.params, cache, tok[:, None],
                                          jnp.asarray(positions))
                with self._lock:
                    self.counters["decode_steps"] += 1
            if hasattr(pilot, "beat"):
                pilot.beat()    # a busy decode loop vouches for liveness

    def _complete(self, req: ServeRequest, tokens: List[int]) -> None:
        """Finish a request exactly once: a replica finishing a request
        in the same instant the reaper recovers it (or two replicas
        racing after a failover re-run) must not double-count."""
        with self._lock:
            if req.done:
                return
            req._finish(tokens)
            self._completed += 1
            self._done_cond.notify_all()

    def _flush_pages(self, req: ServeRequest, out: List[int]) -> None:
        """Rewrite the request's KV-page partition (prompt + everything
        generated) in the home tier and write it through to the durable
        checkpoint home — the state a failover re-prefills from."""
        full = np.concatenate([
            req.prompt,
            np.asarray(req.prior + out, dtype=np.int32)])
        self.kv.update_partition(req.rid, full)
        if self._durable:
            self.kv.persist(parts=[req.rid])

    # -- failover --------------------------------------------------------
    def _reaper_loop(self, interval_s: float) -> None:
        while not self._reaper_stop.wait(interval_s):
            try:
                self._reap_once()
            except Exception:   # noqa: BLE001 - reaping races teardown
                pass

    def _reap_once(self) -> None:
        """One failover sweep: recover requests owed by dead replicas,
        adopt pilots the supervisor respawned, and re-route anything
        parked while the fleet was fully quarantined."""
        with self._lock:
            reps = list(self._replicas.items())
        for pid, rep in reps:
            crashed = rep.task is not None and rep.task.done
            if (not rep.dead and not crashed
                    and rep.pilot.state is State.RUNNING):
                continue
            if not rep.dead:    # loop didn't self-detect (e.g. it crashed)
                with self._lock:
                    self.counters["replica_deaths"] += 1
            self._retire_replica(pid, rep)
        # adopt respawned and scaled-out pilots (fresh ids; respawn and
        # scale-out share the provision path) — but never a draining one:
        # a drained-but-still-RUNNING victim must not be instantly
        # re-adopted while the autoscaler evacuates it
        pds = self.session.data_service
        draining = getattr(self.session.manager.policy, "draining",
                           frozenset())
        with self._lock:
            known = set(self._replicas)
        for p in self.session.pilots:
            if (p.state is State.RUNNING and p.id not in known
                    and p.id not in draining and pds.knows(p.id)):
                self._attach_replica(p)
        with self._lock:
            parked = list(self._unrouted)
            self._unrouted.clear()
        for req in parked:
            self._route(req)

    def _retire_replica(self, pid: str, rep: _Replica) -> None:
        """Take one replica out of the fleet and re-home every request it
        owes — the single retirement path shared by reaped-dead replicas
        and autoscaler-drained live ones."""
        rep.dead = True
        rep.stop.set()
        rep.wake()
        # join the resident loop before draining so the row map is
        # quiescent — no request can be half-owned during recovery
        if rep.task is not None:
            try:
                rep.task.result(timeout=5.0)
            except Exception:   # noqa: BLE001 - crash IS the signal
                pass
        with self._lock:
            self._replicas.pop(pid, None)
        for req in rep.drain():
            if not req.done:
                self._recover(req)

    def drain_replica(self, pilot_id: str) -> int:
        """Hand off a still-healthy replica ahead of scale-in: stop its
        decode loop and recover its in-flight requests from durable KV
        pages exactly like a reaped dead replica's.  Returns the number
        of requests handed off; 0 when the pilot serves no replica."""
        with self._lock:
            rep = self._replicas.get(pilot_id)
        if rep is None:
            return 0
        owed = len(rep.queue) + len(rep.active)
        self._retire_replica(pilot_id, rep)
        with self._lock:
            self.counters["drained_replicas"] += 1
        return owed

    def load(self) -> dict:
        """The autoscaler's serving signal: routed-but-unfinished request
        count and the oldest such request's age."""
        now = time.perf_counter()
        oldest: Optional[float] = None
        queued = 0
        with self._lock:
            reps = list(self._replicas.values())
            unrouted = list(self._unrouted)
        waiting: List[ServeRequest] = list(unrouted)
        for rep in reps:
            with rep.cond:
                waiting.extend(rep.queue)
        for req in waiting:
            if req.done:
                continue
            queued += 1
            if oldest is None or req.t_submit < oldest:
                oldest = req.t_submit
        return {"queued": queued,
                "oldest_wait_s": 0.0 if oldest is None else now - oldest}

    def _recover(self, req: ServeRequest) -> None:
        """Rebuild a request from the durable tier: the KV-page partition
        (home placement, falling back to the checkpoint store through the
        normal fetch chain) holds prompt + generated-so-far as of the
        last page flush; the tail since then is re-decoded — identical
        under greedy decoding, and exactly counted either way."""
        try:
            pages = np.asarray(self.kv.partition(req.rid),
                               dtype=np.int32).reshape(-1)
        except (KeyError, FileNotFoundError):
            pages = req.prompt
        plen = len(req.prompt)
        req.prior = [int(t) for t in pages[plen:]]
        req.ctx = pages if len(pages) > plen else req.prompt
        if len(req.prior) >= req.max_new_tokens:
            # every token was already durable: complete without a re-run
            self._complete(req, list(req.prior[:req.max_new_tokens]))
            return
        req.recoveries += 1
        with self._lock:
            self.counters["recovered_requests"] += 1
        self._route(req)

    # -- waiting / teardown ----------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self._done_cond:
            while self._completed < len(self._requests):
                rem = (None if deadline is None
                       else deadline - time.monotonic())
                if rem is not None and rem <= 0:
                    raise TimeoutError(
                        f"{len(self._requests) - self._completed} requests "
                        f"still in flight after {timeout}s")
                self._done_cond.wait(rem if rem is None else min(rem, 0.1))

    def close(self, timeout: float = 10.0) -> None:
        """Stop the reaper and every resident decode loop (idempotent);
        the session (and the shard/KV DataUnits) stay open — they are the
        caller's."""
        if self._closed:
            return
        self._closed = True
        engines = getattr(self.session, "serving_engines", None)
        if engines is not None and self in engines:
            engines.remove(self)
        self._reaper_stop.set()
        if self._reaper is not None:
            self._reaper.join(timeout)
        with self._lock:
            reps = list(self._replicas.values())
        for rep in reps:
            rep.stop.set()
            rep.wake()
        for rep in reps:
            if rep.task is not None:
                try:
                    rep.task.result(timeout=timeout)
                except Exception:   # noqa: BLE001 - dead replica loops
                    pass

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    # -- telemetry -------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            reqs = list(self._requests)
            completed = self._completed
            replicas = {pid: {"dead": rep.dead,
                              "queued": len(rep.queue),
                              "active_rows": len(rep.active)}
                        for pid, rep in self._replicas.items()}
            unrouted = len(self._unrouted)
        lats = sorted(r.latency_s for r in reqs
                      if r.latency_s is not None)
        out = dict(self.counters)
        out.update({
            "requests": len(reqs), "completed": completed,
            "unrouted": unrouted, "replicas": replicas,
            "p50_latency_s": _pct(lats, 0.50),
            "p99_latency_s": _pct(lats, 0.99),
        })
        return out

    def __repr__(self) -> str:
        return (f"ServingEngine({self.name!r}, replicas="
                f"{len(self._replicas)}, batch={self.batch_size}, "
                f"requests={len(self._requests)})")


def _pct(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return float(sorted_vals[i])


def _noop():
    return None
