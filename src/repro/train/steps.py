"""Train / prefill / decode step builders + abstract input specs.

``make_train_step`` returns (step_fn, state_specs): pure functions over a
TrainState pytree, ready for jax.jit with in/out shardings resolved from the
AxisRules table. Microbatching (gradient accumulation) runs as a lax.scan so
activation memory scales with the microbatch, not the global batch.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ParallelConfig, ShapeConfig,
                                TrainConfig)
from repro.models.common import cross_entropy_loss
from repro.models.model import Model
from repro.optim.adamw import OptState, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine
from repro.parallel.sharding import (AxisRules, resolve_pspec,
                                     sharding_context)

MOE_AUX_COEF = 0.01
MTP_COEF = 0.3


class TrainState(NamedTuple):
    params: Any
    opt_state: OptState


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def compute_loss(model: Model, params, batch, tcfg: TrainConfig):
    out = model.train_forward(params, batch)
    labels = batch["labels"]
    loss = cross_entropy_loss(out["logits"], labels, z_loss=tcfg.z_loss)
    total = loss + MOE_AUX_COEF * out["aux"]
    metrics = {"loss": loss, "aux": out["aux"]}
    if "mtp_logits" in out:
        mtp_labels = jnp.roll(labels, -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        mtp = cross_entropy_loss(out["mtp_logits"], mtp_labels, mask=mask)
        total = total + MTP_COEF * mtp
        metrics["mtp_loss"] = mtp
    metrics["total_loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(model: Model, pcfg: ParallelConfig, tcfg: TrainConfig):
    def train_step(state: TrainState, batch):
        def loss_fn(params, mb):
            return compute_loss(model, params, mb, tcfg)

        if pcfg.microbatches > 1:
            n = pcfg.microbatches
            mb_batch = jax.tree.map(
                lambda t: t.reshape((n, t.shape[0] // n) + t.shape[1:]), batch)

            def acc(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, grads)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            m0 = {"loss": 0.0, "aux": 0.0, "total_loss": 0.0}
            if model.cfg.mtp_depth:
                m0["mtp_loss"] = 0.0
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc, (g0, m0), mb_batch)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: m / n, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)

        lr = warmup_cosine(state.opt_state.count, tcfg)
        new_params, new_opt, gnorm = adamw_update(
            grads, state.opt_state, state.params, lr, tcfg,
            state_dtype=pcfg.opt_state_dtype)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(model: Model, key, pcfg: ParallelConfig) -> TrainState:
    params = model.init(key)
    return TrainState(params, adamw_init(params, pcfg.opt_state_dtype))


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, positions):
        return model.decode(params, cache, tokens, positions)
    return decode_step


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct) + logical axes, per (arch x shape)
# ---------------------------------------------------------------------------

def batch_logical(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    """name -> ((shape), (logical axes), dtype) for the input batch."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {
            "tokens": ((b, 1), ("batch", None), jnp.int32),
            "positions": ((b,), ("batch",), jnp.int32),
        }
    st = s - cfg.vision_tokens
    out = {"tokens": ((b, st), ("batch", "seq"), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = ((b, st), ("batch", "seq"), jnp.int32)
    if cfg.vision_tokens:
        out["patch_embeds"] = ((b, cfg.vision_tokens, cfg.vision_embed_dim),
                               ("batch", None, None), jnp.bfloat16)
    if cfg.encoder_layers:
        out["frames"] = ((b, cfg.encoder_seq_len, cfg.d_model),
                         ("batch", None, "act_embed"), jnp.bfloat16)
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: AxisRules):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the batch."""
    logical = batch_logical(cfg, shape)
    sds = {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, lg, dt) in logical.items()}
    pspecs = {k: resolve_pspec(lg, sh, mesh, rules)
              for k, (sh, lg, dt) in logical.items()}
    return sds, pspecs


def _cache_leaf_dtype(path) -> Any:
    """Cache dtype by leaf name: pos -> int32, ssm state -> fp32, else bf16."""
    keys = [getattr(p, "key", None) for p in path]
    if keys and keys[-1] == "pos":
        return jnp.int32
    if keys and keys[-1] == "ssm":
        return jnp.float32
    return jnp.bfloat16


def cache_specs(model: Model, shape: ShapeConfig, mesh, rules: AxisRules):
    """(SDS tree, pspec tree) for the decode cache at this shape."""
    spec = model.cache_spec(shape.global_batch, shape.seq_len)
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and isinstance(x[0], tuple)
                         and all(isinstance(i, int) for i in x[0]))
    sds = jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.ShapeDtypeStruct(leaf[0], _cache_leaf_dtype(path)),
        spec, is_leaf=is_leaf)
    ps = jax.tree.map(lambda leaf: resolve_pspec(leaf[1], leaf[0], mesh, rules),
                      spec, is_leaf=is_leaf)
    return sds, ps
