"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) expert d_ff=16384
vocab=32768, 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                       # all FFNs are MoE
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=16384),
    source="arXiv:2401.04088 (hf)",
)
