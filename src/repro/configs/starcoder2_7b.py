"""starcoder2-7b [dense]: 32L d=4608 36H (GQA kv=4) d_ff=18432 vocab=49152,
GQA + RoPE [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    ffn_act="gelu",
    source="arXiv:2402.19173 (hf)",
)
