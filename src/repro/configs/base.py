"""Config system: architecture, shape, parallelism and run configs.

Every assigned architecture gets one module in ``repro.configs`` exposing
``CONFIG: ModelConfig``. Shapes are global (same four cells for every LM arch).
All configs are plain frozen dataclasses so they hash, print and diff cleanly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts (0 = dense model)
    num_shared_experts: int = 0     # always-on experts (DeepSeek style)
    top_k: int = 2
    expert_d_ff: int = 0            # per-expert intermediate size
    capacity_factor: float = 1.25
    router_aux_free: bool = False   # DeepSeek-V3 bias-based balancing
    router_scale: float = 1.0       # routed_scaling_factor
    first_k_dense: int = 0          # leading dense layers (DeepSeek-V3: 3)
    first_dense_d_ff: int = 0       # ffn width of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM."""
    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2                 # d_inner = expand * d_model
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)
    scan_dtype: str = "float32"     # chunk-scan operand dtype (see ssm.py)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, -(-d_model // 16))


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # attention flavour
    attention: str = "gqa"          # gqa | mla | none (pure ssm)
    sliding_window: int = 0         # 0 = full attention; >0 = SWA width
    global_attn_layers: Tuple[int, ...] = ()   # hybrid: layers w/ full attn
    rope_theta: float = 10000.0
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (hymba): run attention and SSM heads in parallel per layer
    parallel_ssm: bool = False
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq_len: int = 0        # frames from the (stubbed) conv frontend
    # vlm (internvl): stubbed ViT patch embeddings + projector
    vision_tokens: int = 0
    vision_embed_dim: int = 0
    # misc
    tie_embeddings: bool = False
    ffn_act: str = "swiglu"      # swiglu (3-matrix) | gelu (2-matrix)
    norm_eps: float = 1e-5
    mtp_depth: int = 0              # DeepSeek-V3 multi-token prediction depth
    dtype: str = "bfloat16"
    # scan-over-layers for compact HLO; unrolled when layer stack heterogeneous
    scan_layers: bool = True
    decode_kernel: bool = False     # use the Pallas flash-decoding kernel
    remat: str = "full"             # full | dots | none
    # source note for provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def supports_long_decode(self) -> bool:
        """True iff the decode path is sub-quadratic / bounded-state."""
        if self.is_attention_free:
            return True
        if self.parallel_ssm:  # hybrid: SWA + few global layers
            return True
        # SWA-everywhere models keep a rolling cache
        return self.sliding_window > 0 and not self.global_attn_layers

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an AR decoder (whisper = enc-dec)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, h = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        per_layer = 0
        if self.attention == "gqa":
            per_layer += d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d
        elif self.attention == "mla":
            m = self.mla
            qh = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * nq * qh
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += nq * m.v_head_dim * d
        if self.ssm is not None:
            di = self.ssm.expand * d
            dt = self.ssm.resolved_dt_rank(d)
            s = self.ssm.state_dim
            per_layer += d * 2 * di                      # in_proj (x, z)
            per_layer += di * self.ssm.conv_kernel       # conv1d
            per_layer += di * (dt + 2 * s) + dt * di     # x_proj + dt_proj
            per_layer += di * s + di                     # A_log, D
            per_layer += di * d                          # out_proj
        ffn_mats = 3 if self.ffn_act == "swiglu" else 2
        if self.is_moe:
            e = self.moe
            moe_layer = (e.num_experts + e.num_shared_experts) * 3 * d * e.expert_d_ff
            moe_layer += d * e.num_experts               # router
            dense_layer = ffn_mats * d * self.d_ff if self.d_ff else 0
            n_moe = self.num_layers - e.first_k_dense
            per_layer_ffn = 0  # accounted per-kind below
            total_ffn = n_moe * moe_layer + e.first_k_dense * dense_layer
        else:
            total_ffn = self.num_layers * (ffn_mats * d * self.d_ff if self.d_ff else 0)
        per_layer += 2 * d                               # norms
        total = self.num_layers * per_layer + total_ffn
        total += self.vocab_size * d                     # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * d + ffn_mats * d * self.d_ff + 2 * d)
            total += enc + self.encoder_layers * (4 * d * d)  # cross-attn
        if self.vision_tokens:
            total += self.vision_embed_dim * d + d * d   # projector (2-layer)
        return int(total)

    def num_active_params(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if not self.is_moe:
            return self.num_params()
        e = self.moe
        d = self.d_model
        all_experts = e.num_experts * 3 * d * e.expert_d_ff
        active_experts = (e.top_k + e.num_shared_experts) * 3 * d * e.expert_d_ff
        n_moe = self.num_layers - e.first_k_dense
        return int(self.num_params() - n_moe * (all_experts + e.num_shared_experts * 3 * d * e.expert_d_ff) + n_moe * active_experts)


# ---------------------------------------------------------------------------
# Input shapes (assigned cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Parallelism / run configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    """How logical axes map onto the mesh; the §Perf hillclimb edits this."""
    dp_axes: Tuple[str, ...] = ("pod", "data")   # batch axes
    tp_axis: str = "model"                       # tensor-parallel axis
    fsdp_axis: str = "data"                      # param/optimizer shard axis ("" = pure DP)
    ep_axis: str = "model"                       # expert-parallel axis
    sp_axis: str = "data"                        # sequence-parallel axis for prefill
    shard_params_over_fsdp: bool = True
    shard_opt_state: bool = True                 # ZeRO-1
    sequence_parallel: bool = True               # shard long-seq activations
    vocab_parallel: bool = True
    remat: str = "full"
    microbatches: int = 1
    opt_state_dtype: str = "float32"             # float32 | bfloat16 | int8
    extra_rules: Tuple[Tuple[str, Optional[str]], ...] = ()

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 100
    grad_compression: str = "none"   # none | int8_ef


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes: dict = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        scan_layers=cfg.scan_layers,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        global_attn_layers=tuple(i for i in cfg.global_attn_layers if i < 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 16) if cfg.encoder_seq_len else 0,
        vision_tokens=min(cfg.vision_tokens, 4) if cfg.vision_tokens else 0,
        vision_embed_dim=32 if cfg.vision_embed_dim else 0,
        mtp_depth=cfg.mtp_depth,
        name=cfg.name + "-smoke",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
        )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_dim=4, dt_rank=8)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
