"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free vocab=65024 ssm_state=16.
Mamba-1 architecture [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,                       # attn-free, no separate FFN (mamba block only)
    vocab_size=65024,
    attention="none",
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2),
    source="arXiv:2410.05355 (unverified)",
)
