"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, MoE 256e top-8 + 1 shared,
expert d_ff=2048, vocab=129280, MTP, first 3 layers dense [arXiv:2412.19437]."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,             # MLA: latent-compressed, per-head on expand
    head_dim=192,                 # qk_nope(128) + qk_rope(64)
    d_ff=2048,                    # per-expert intermediate (assigned value)
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, num_shared_experts=1, top_k=8,
                  expert_d_ff=2048, router_aux_free=True, router_scale=2.5,
                  first_k_dense=3, first_dense_d_ff=18432),
    mtp_depth=1,
    source="arXiv:2412.19437 (hf)",
)
