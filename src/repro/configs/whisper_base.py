"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H d_ff=2048 vocab=51865,
enc-dec; conv frontend is a stub (precomputed frame embeddings)
[arXiv:2212.04356]. RoPE replaces whisper's sinusoidal/learned positions
(TPU-idiomatic; noted in DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_seq_len=1500,         # 30s of audio at 50 frames/s (stub)
    ffn_act="gelu",
    source="arXiv:2212.04356 (unverified)",
)
