"""internvl2-2b [vlm]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
InternViT frontend is a stub (precomputed patch embeddings) + projector
[arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    vision_tokens=256,            # 448x448 / 14 patch / pixel-shuffle 2x2
    vision_embed_dim=1024,        # InternViT-300M width
    source="arXiv:2404.16821 (hf)",
)
