"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                ParallelConfig, SHAPES, ShapeConfig, SSMConfig,
                                TrainConfig, reduced)

ARCH_IDS: List[str] = [
    "falcon_mamba_7b",
    "mixtral_8x22b",
    "deepseek_v3_671b",
    "internvl2_2b",
    "hymba_1_5b",
    "deepseek_67b",
    "yi_9b",
    "starcoder2_7b",
    "llama3_2_1b",
    "whisper_base",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "falcon-mamba-7b": "falcon_mamba_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "internvl2-2b": "internvl2_2b",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-67b": "deepseek_67b",
    "yi-9b": "yi_9b",
    "starcoder2-7b": "starcoder2_7b",
    "llama3.2-1b": "llama3_2_1b",
    "whisper-base": "whisper_base",
})


def get_config(arch: str) -> ModelConfig:
    arch_id = _ALIASES.get(arch, arch)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
