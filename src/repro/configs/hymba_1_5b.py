"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attn+mamba heads, SWA everywhere except 3 global layers
[arXiv:2411.13676]. Meta tokens are not modeled (noted in DESIGN.md)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    parallel_ssm=True,
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2),
    source="arXiv:2411.13676 (hf)",
)
