"""Sharded checkpointing with async write and elastic restore.

Checkpoints are Pilot-Data DataUnits in the persistent (file) tier: the
trainer's state pytree is flattened to named leaves, each saved as one
partition-file, with a JSON manifest (step, tree structure, shapes, dtypes).

Elastic restore: leaves are loaded as host arrays and device_put with the
*restoring* mesh's shardings — a checkpoint written on 512 chips restores
onto 256 (or 1) without conversion, which is the re-mesh path the runtime
uses after a (simulated) pod loss. int8-quantized optimizer states (QTensor)
round-trip through their (data, scale) leaves transparently.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

from repro.optim.quant import QTensor

# dtypes numpy can't serialize natively -> stored as a same-width uint view
_EXTENDED = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
             "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
             "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _encode(arr: np.ndarray):
    for name, (dt, view) in _EXTENDED.items():
        if arr.dtype == dt:
            return arr.view(view), name
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _EXTENDED:
        return arr.view(_EXTENDED[dtype][0])
    return arr


def _flatten_named(tree) -> Dict[str, Any]:
    flat: Dict[str, Any] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None
        self.write_log: list = []

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def save(self, step: int, state, blocking: bool = True) -> Path:
        """Snapshot to host memory synchronously, write to disk (optionally
        in the background so the next train step overlaps the I/O)."""
        self.wait()  # never two writers in flight (same-step dir races)
        t0 = time.time()
        host = jax.tree.map(np.asarray, jax.device_get(state))
        snap_t = time.time() - t0

        def write():
            tw0 = time.time()
            d = self._step_dir(step)
            tmp = d.with_suffix(".tmp")
            tmp.mkdir(parents=True, exist_ok=True)
            flat = _flatten_named(host)
            manifest = {"step": step, "leaves": {}}
            for key, leaf in flat.items():
                fname = key.replace("/", "__") + ".npy"
                enc, dtype_name = _encode(np.asarray(leaf))
                np.save(tmp / fname, enc)
                manifest["leaves"][key] = {
                    "file": fname,
                    "shape": list(np.asarray(leaf).shape),
                    "dtype": dtype_name,
                }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if d.exists():
                import shutil
                shutil.rmtree(d)
            tmp.rename(d)
            self._gc()
            self.write_log.append({"step": step, "snapshot_s": snap_t,
                                   "write_s": time.time() - tw0})

        if blocking:
            write()
        else:
            self.wait()
            self._async_thread = threading.Thread(target=write, daemon=True)
            self._async_thread.start()
        return self._step_dir(step)

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def list_steps(self):
        return [int(p.name.split("_")[1]) for p in self.root.glob("step_*")
                if p.is_dir()]

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def restore(self, like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding
        for the *current* mesh — this is the elastic re-mesh path."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        flat_sh = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(flat_like))
        leaves = []
        for (path, leaf), sh in zip(flat_like, flat_sh):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                           for p in path)
            info = manifest["leaves"][key]
            arr = _decode(np.load(d / info["file"]), info["dtype"])
            if sh is not None:
                arr = jax.device_put(arr, sh)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), step
