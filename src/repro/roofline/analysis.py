"""Three-term roofline from a compiled dry-run artifact (no hardware needed).

  compute   = HLO_FLOPs_per_device / peak_FLOPs
  memory    = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

cost_analysis() on a post-SPMD executable reports *per-device* flops/bytes
(verified empirically), so terms divide by per-chip peaks directly.
collective_bytes comes from parsing the optimized HLO: we sum the serialized
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting each op kind by the traffic its ring/neighbor
implementation moves per device relative to the shard size.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(constants per assignment).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link
HBM_PER_CHIP = 16 * 1024 ** 3

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:[a-z0-9]+\[[^\]]*\](?:,\s*)?)+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# Per-device traffic multiplier relative to the op's *output* buffer size,
# for ring implementations over a group of size g:
#   all-reduce: 2*(g-1)/g x (reduce-scatter + all-gather)
#   all-gather: (g-1)/g of the full output
#   reduce-scatter: (g-1)/g of the full input
#   all-to-all: (g-1)/g of the buffer
#   collective-permute: 1x
def _traffic_factor(kind: str, group: int) -> float:
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (group - 1) / group
    if kind == "collective-permute":
        return 1.0
    return (group - 1) / group


_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, nbytes: float):
        self.total_bytes += nbytes
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + nbytes
        self.count += 1


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from optimized HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COLLECTIVE_RE.search(s)
        if not m:
            continue
        if s.startswith("ROOT"):
            s = s[4:].strip()
        shape_str, kind = m.group(2), m.group(3).lower()
        buf = _shape_bytes(shape_str)
        g = _group_size(s)
        stats.add(kind, buf * _traffic_factor(kind, g))
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: Dict[str, float]
    peak_mem_bytes: float
    arg_bytes: float
    model_flops: float            # 6*N*D (global, analytic)
    hlo_flops_global: float
    extras: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """compute-term / max-term: 1.0 = perfectly compute-bound."""
        t = self.roofline_time
        return self.t_compute / t if t > 0 else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        return (self.model_flops / self.hlo_flops_global
                if self.hlo_flops_global else 0.0)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 roofline_fraction=self.roofline_fraction,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def analyze(compiled, hlo_text: str, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float) -> Roofline:
    """Roofline terms from the compiled artifact.

    Uses the HLO-walking cost model (repro.roofline.hlo_cost), NOT
    compiled.cost_analysis(): XLA's analysis counts while (scan) bodies once,
    undercounting scanned layer stacks by ~num_layers x (verified).
    """
    from repro.roofline.hlo_cost import HloCostModel
    model = HloCostModel(hlo_text)
    c = model.cost()
    mem = compiled.memory_analysis()
    if mem is not None:
        resident = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
        peak = float(max(getattr(mem, "peak_memory_in_bytes", 0) or 0, resident))
        args = float(mem.argument_size_in_bytes)
    else:
        peak = args = 0.0
    xla_cost = compiled.cost_analysis() or {}
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=c.flops, bytes_per_device=c.hbm_bytes,
        coll_bytes_per_device=c.coll_bytes, coll_by_kind=c.coll_by_kind,
        peak_mem_bytes=peak, arg_bytes=args, model_flops=model_flops,
        hlo_flops_global=c.flops * chips)
    r.extras = {
        "xla_cost_flops_per_device": float(xla_cost.get("flops", 0.0)),
        "top_opcode_bytes": dict(sorted(c.by_opcode_bytes.items(),
                                        key=lambda kv: -kv[1])[:10]),
        "num_collectives": c.coll_count,
        "while_trip_counts": sorted({t for _, t, _ in model.while_loops}),
    }
    return r


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D with N = active params (MoE) — the 'useful' flop floor."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per row
