"""Generate the EXPERIMENTS.md roofline tables from the recorded JSONs.

    python -m repro.roofline.report [--dryrun-dir ...] [--perf-dir ...]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]


def _fmt(t: float) -> str:
    if t == 0:
        return "0"
    if t < 1e-3:
        return f"{t*1e6:.0f}us"
    if t < 1:
        return f"{t*1e3:.1f}ms"
    return f"{t:.2f}s"


def load(d: Path):
    recs = []
    for f in sorted(d.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def dryrun_table(recs, mesh: str) -> str:
    lines = ["| arch | shape | t_compute | t_memory | t_collective | bottleneck"
             " | roofline frac | useful flops | peak GiB | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR |||||||")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(ro['t_compute'])} | "
            f"{_fmt(ro['t_memory'])} | {_fmt(ro['t_collective'])} | "
            f"{ro['bottleneck']} | {ro['roofline_fraction']:.3f} | "
            f"{ro['useful_flops_ratio']:.2f} | "
            f"{ro['peak_mem_bytes']/2**30:.1f} | "
            f"{'yes' if r.get('fits_hbm') else 'no'} |")
    return "\n".join(lines)


def perf_table(recs) -> str:
    lines = ["| cell / variant | t_compute | t_memory | t_collective | "
             "bottleneck | peak GiB |",
             "|---|---|---|---|---|---|"]
    for r in recs:
        tag = r.get("tag", "?")
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']}/{r['shape']} {tag} | ERROR |||||")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']}/{r['shape']} **{tag}** | {_fmt(ro['t_compute'])} | "
            f"{_fmt(ro['t_memory'])} | {_fmt(ro['t_collective'])} | "
            f"{ro['bottleneck']} | {ro['peak_mem_bytes']/2**30:.1f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default=str(ROOT / "experiments/dryrun"))
    ap.add_argument("--perf-dir", default=str(ROOT / "experiments/perf"))
    args = ap.parse_args()
    recs = load(Path(args.dryrun_dir))
    print("### Single-pod 16x16 (256 chips)\n")
    print(dryrun_table(recs, "16x16"))
    print("\n### Multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table(recs, "2x16x16"))
    perf = load(Path(args.perf_dir))
    if perf:
        print("\n### Perf variants\n")
        print(perf_table(perf))


if __name__ == "__main__":
    main()
