"""HLO-text cost model with while-loop trip-count multiplication.

XLA's built-in ``compiled.cost_analysis()`` counts every computation ONCE —
a jax.lax.scan over 61 layers reports 1/61st of the real FLOPs (verified
empirically). This walker parses the *optimized* HLO text, builds a per-
computation symbol table (operands are %name references), resolves
fusion/call/while/conditional edges, multiplies while bodies by their parsed
trip counts, and produces:

  flops            (dot ops: 2 * prod(out) * prod(lhs contracting dims))
  hbm_bytes        (operands + outputs of top-level ops; fused interiors free)
  collective bytes (ring-model traffic per device, by op kind)

Per-opcode and per-loop breakdowns double as the "profile" the §Perf
hillclimb reads — there is no wall-clock on a CPU dry-run.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->.*\{")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"                     # result name
    r"((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s*"   # result shape
    r"([\w\-]+)\("                                          # opcode
)
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUP_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_NAME_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_ZERO_COST = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
              "after-all", "iota", "partition-id", "replica-id"}


def shape_bytes(shape_str: str) -> float:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in filter(None, dims.split(",")):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return float(total)


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in filter(None, m.group(2).split(",")):
        n *= int(d)
    return n


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in filter(None, m.group(2).split(","))]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_shape: str
    line: str
    operands: Tuple[str, ...]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    by_opcode_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_count += int(other.coll_count * mult)
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.by_opcode_bytes.items():
            self.by_opcode_bytes[k] = self.by_opcode_bytes.get(k, 0.0) + v * mult


def _traffic_factor(kind: str, group: int) -> float:
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (group - 1) / group
    if kind == "collective-permute":
        return 1.0
    return (group - 1) / group


def _group_size(line: str) -> int:
    m = _GROUP_PAIR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _call_operands(line: str) -> Tuple[str, ...]:
    """Names inside the first balanced paren group after the opcode."""
    i = line.index("(")
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return tuple(_NAME_RE.findall(line[i:j + 1]))
    return tuple(_NAME_RE.findall(line[i:]))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, Dict[str, Op]] = {}
        self._order: List[str] = []
        self._parse(hlo_text)
        self._cost_cache: Dict[str, Cost] = {}
        self.while_loops: List[Tuple[str, int, Cost]] = []

    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith(("//", "#")):
                continue
            if cur is None:
                m = _COMP_START_RE.match(line)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = {}
                    self._order.append(cur)
                continue
            if line.startswith("}"):
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                name, shape, opcode = m.group(1), m.group(2), m.group(3)
                # strip the result-shape part so operand search starts at call
                call_line = line[m.end() - len(opcode) - 1:]
                self.computations[cur][name] = Op(
                    name, opcode, shape, line, _call_operands(call_line))

    def entry_name(self) -> str:
        for name in self._order:
            if name.startswith("main"):
                return name
        return self._order[-1] if self._order else ""

    def _operand_shape(self, comp: Dict[str, Op], name: str) -> str:
        op = comp.get(name)
        return op.result_shape if op else ""

    def _trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name, {})
        consts = {}
        bound = None
        for op in comp.values():
            mc = _CONST_RE.search(op.line)
            if op.opcode == "constant" and mc:
                consts[op.name] = int(mc.group(1))
        for op in comp.values():
            if op.opcode == "compare":
                inline = _CONST_RE.search(op.line)
                if inline:
                    bound = int(inline.group(1))
                else:
                    for operand in op.operands:
                        if operand in consts:
                            bound = consts[operand]
        if bound is None and consts:
            bound = max(consts.values())
        return max(int(bound or 1), 1)

    def cost(self, comp_name: Optional[str] = None,
             top_level: bool = True) -> Cost:
        comp_name = comp_name or self.entry_name()
        key = f"{comp_name}|{top_level}"
        if key in self._cost_cache:
            return self._cost_cache[key]
        total = Cost()
        comp = self.computations.get(comp_name, {})
        for op in comp.values():
            total.add(self._op_cost(comp, op, top_level))
        self._cost_cache[key] = total
        return total

    def _io_bytes(self, comp: Dict[str, Op], op: Op) -> float:
        b = shape_bytes(op.result_shape)
        for operand in op.operands:
            b += shape_bytes(self._operand_shape(comp, operand))
        return b

    def _dot_flops(self, comp: Dict[str, Op], op: Op) -> float:
        out_elems = shape_elems(op.result_shape)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        if not m or not op.operands:
            return 2.0 * out_elems
        lhs_dims = _shape_dims(self._operand_shape(comp, op.operands[0]))
        contract = 1
        for idx in filter(None, m.group(1).split(",")):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
        return 2.0 * out_elems * contract

    def _op_cost(self, comp: Dict[str, Op], op: Op, top_level: bool) -> Cost:
        c = Cost()
        oc = op.opcode
        if oc in _ZERO_COST:
            return c
        if oc == "while":
            cond = _COND_RE.search(op.line)
            body = _BODY_RE.search(op.line)
            trips = self._trip_count(cond.group(1)) if cond else 1
            if body:
                body_cost = self.cost(body.group(1), top_level=True)
                c.add(body_cost, mult=trips)
                self.while_loops.append((body.group(1), trips, body_cost))
            return c
        if oc == "fusion":
            m = _CALLS_RE.search(op.line)
            if m:
                inner = self.cost(m.group(1), top_level=False)
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                c.coll_count += inner.coll_count
                for k, v in inner.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
            if top_level:
                b = self._io_bytes(comp, op)
                c.hbm_bytes += b
                c.by_opcode_bytes["fusion"] = c.by_opcode_bytes.get("fusion", 0.0) + b
            return c
        if oc in ("call", "custom-call", "conditional", "async-start"):
            m = _CALLS_RE.search(op.line)
            if m:
                c.add(self.cost(m.group(1), top_level=top_level))
            if top_level:
                b = self._io_bytes(comp, op)
                c.hbm_bytes += b
                c.by_opcode_bytes[oc] = c.by_opcode_bytes.get(oc, 0.0) + b
            return c
        base = oc.replace("-start", "")
        if base in COLLECTIVES:
            if oc.endswith("-done"):
                return c
            buf = shape_bytes(op.result_shape)
            g = _group_size(op.line)
            traffic = buf * _traffic_factor(base, g)
            c.coll_bytes += traffic
            c.coll_count += 1
            c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + traffic
            if top_level:
                b = self._io_bytes(comp, op)
                c.hbm_bytes += b
                c.by_opcode_bytes[base] = c.by_opcode_bytes.get(base, 0.0) + b
            return c
        if oc == "dot":
            c.flops += self._dot_flops(comp, op)
        elif oc == "convolution":
            c.flops += 2.0 * shape_elems(op.result_shape) * 32  # coarse
        if top_level:
            b = self._io_bytes(comp, op)
            c.hbm_bytes += b
            c.by_opcode_bytes[oc] = c.by_opcode_bytes.get(oc, 0.0) + b
        return c


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).cost()
