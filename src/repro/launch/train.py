"""End-to-end training driver on the Pilot stack.

    python -m repro.launch.train --arch llama3_2_1b --preset 100m \
        --steps 300 --batch 8 --seq 512

Flow (paper Fig. 3): corpus lives as a file-tier DataUnit -> staged to the
host tier by the pipeline -> batches feed the jitted train_step running on a
PilotCompute that retains the mesh + compiled step across the whole run ->
checkpoints write back to the persistent tier asynchronously. --failure-at
injects a simulated pilot loss to demonstrate checkpoint/restart recovery.

Presets scale the *width/depth* of the chosen architecture family while
keeping its structure (GQA ratios, MoE top-k, SSM dims), so every assigned
arch has a runnable small variant: smoke (~1M), 20m, 100m (the e2e target).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig, reduced
from repro.core import (ComputeDataManager, DataUnit, PilotComputeDescription,
                        PilotComputeService, make_backend)
from repro.data.pipeline import BatchPipeline, corpus_data_unit
from repro.models.common import param_count, param_pspecs
from repro.models.model import build_model
from repro.parallel.sharding import AxisRules, sharding_context
from repro.train import steps as steps_mod
from repro.train.steps import TrainState

PRESETS = {
    "smoke": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=512, head_dim=16),
    "20m": dict(num_layers=4, d_model=384, num_heads=6, num_kv_heads=2,
                d_ff=1024, vocab_size=8192, head_dim=64),
    "100m": dict(num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=2048, vocab_size=16384, head_dim=64),
    "full": {},
}


def scaled_config(arch: str, preset: str) -> ModelConfig:
    cfg = get_config(arch)
    if preset == "full":
        return cfg
    if preset == "smoke":
        return reduced(cfg)
    over = dict(PRESETS[preset])
    if cfg.is_moe:
        over["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            expert_d_ff=over["d_ff"],
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            first_dense_d_ff=over["d_ff"])
        over["d_ff"] = cfg.d_ff and over["d_ff"]
    if cfg.ssm is not None:
        over["ssm"] = cfg.ssm
        if cfg.d_ff == 0:
            over["d_ff"] = 0
    if cfg.vision_tokens:
        over["vision_tokens"] = min(cfg.vision_tokens, 16)
        over["vision_embed_dim"] = 128
    if cfg.encoder_layers:
        over["encoder_layers"] = min(cfg.encoder_layers, 4)
        over["encoder_seq_len"] = min(cfg.encoder_seq_len, 64)
    over["global_attn_layers"] = tuple(
        i for i in cfg.global_attn_layers if i < over["num_layers"])
    if cfg.sliding_window:
        over["sliding_window"] = min(cfg.sliding_window, 256)
    over["name"] = f"{cfg.name}-{preset}"
    return dataclasses.replace(cfg, **over)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--failure-at", type=int, default=0,
                    help="inject a pilot failure at this step (demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.preset)
    model = build_model(cfg)
    n_params = param_count(model.specs)
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"devices={jax.device_count()}")

    # --- pilot: retained resources for the whole run ---
    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription(
        backend="inprocess", num_devices=jax.device_count(),
        affinity="trainer"))
    manager = ComputeDataManager(svc)
    mesh = pilot.mesh
    rules = AxisRules()

    # --- data: file tier -> host tier -> batches ---
    backends = {"file": make_backend("file", root=str(Path(args.ckpt_dir) / "corpus")),
                "host": make_backend("host")}
    du = corpus_data_unit("corpus", cfg,
                          num_tokens=max(2_000_000, 4 * args.batch
                                         * (args.seq + 1) * 16),
                          backends=backends, tier="file")
    du.to_tier("host", delete_source=False)
    pipe = BatchPipeline(du, cfg, args.batch, args.seq)

    # --- jitted step with shardings resolved from the rules table ---
    pcfg = ParallelConfig(microbatches=args.microbatches,
                          opt_state_dtype=args.opt_dtype)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20))
    step_fn = steps_mod.make_train_step(model, pcfg, tcfg)

    def jit_step():
        def fn(state, batch):
            with sharding_context(mesh, rules):
                return step_fn(state, batch)
        return jax.jit(fn, donate_argnums=(0,))

    jitted = pilot.jit_cached(("train_step", cfg.name), jit_step)
    state = steps_mod.init_train_state(model, jax.random.key(tcfg.seed), pcfg)
    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name)

    start = 0
    if ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[train] restored step {start}")

    t_hist = []
    failed_once = False
    step = start
    while step < args.steps:
        batch = next(pipe)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if args.failure_at and step == args.failure_at and not failed_once:
            failed_once = True
            print(f"[train] !!! injecting pilot failure at step {step}")
            svc.release(pilot)
            pilot = svc.submit_pilot(PilotComputeDescription(
                backend="inprocess", num_devices=jax.device_count(),
                affinity="trainer"))
            mesh = pilot.mesh
            jitted = pilot.jit_cached(("train_step", cfg.name), jit_step)
            state, step = ckpt.restore(state)
            print(f"[train] recovered at step {step}")
            continue
        t0 = time.time()
        cu = manager.run(lambda s=state, b=batch: jitted(s, b),
                         affinity="trainer")
        state, metrics = cu.result()
        metrics["loss"].block_until_ready()
        dt = time.time() - t0
        t_hist.append(dt)
        step += 1
        if step % args.log_every == 0 or step == 1:
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if step % args.ckpt_every == 0:
            ckpt.save(step, state, blocking=False)
    ckpt.save(args.steps, state, blocking=True)
    pipe.close()
    svc.cancel_all()
    med = float(np.median(t_hist)) if t_hist else 0.0
    tokens_s = args.batch * args.seq / med if med else 0.0
    print(f"[train] done: median step {med*1e3:.0f}ms, {tokens_s:.0f} tok/s, "
          f"final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
