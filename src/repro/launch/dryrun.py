"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any jax import: jax locks the device count at
first initialization. Only the dry-run sees 512 placeholder host devices.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ParallelConfig, SHAPES, TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.models.common import abstract_params, param_pspecs
from repro.models.model import build_model
from repro.parallel.sharding import AxisRules, sharding_context
from repro.roofline import analysis as ra
from repro.train import steps as steps_mod
from repro.train.steps import TrainState
from repro.optim.adamw import OptState

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return ("full-attention arch: 512k dense-KV decode is not serveable; "
                "skipped per DESIGN.md §Arch-applicability")
    return None


def build_lowerable(cfg, shape, mesh, rules: AxisRules, pcfg: ParallelConfig):
    """Returns (jitted_fn, example_args) ready for .lower()."""
    model = build_model(cfg)
    params_sds = abstract_params(model.specs)
    params_ps = param_pspecs(model.specs, mesh, rules)
    ns = lambda tree: jax.tree.map(lambda p: NamedSharding(mesh, p), tree)
    batch_sds, batch_ps = steps_mod.batch_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        tcfg = TrainConfig()
        step = steps_mod.make_train_step(model, pcfg, tcfg)
        if pcfg.opt_state_dtype == "bfloat16":
            mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                              params_sds)
        else:
            mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                              params_sds)
        opt_sds = OptState(m=mv, v=jax.tree.map(lambda x: x, mv),
                           count=jax.ShapeDtypeStruct((), jnp.int32))
        opt_ps = OptState(m=params_ps, v=jax.tree.map(lambda x: x, params_ps),
                          count=P())
        state_sds = TrainState(params_sds, opt_sds)
        state_ps = TrainState(ns(params_ps), ns(opt_ps))

        def fn(state, batch):
            with sharding_context(mesh, rules):
                return step(state, batch)

        jitted = jax.jit(fn, in_shardings=(state_ps, ns(batch_ps)),
                         donate_argnums=(0,))
        return jitted, (state_sds, batch_sds)

    if shape.kind == "prefill":
        step = steps_mod.make_prefill_step(model, max_len=shape.seq_len)

        def fn(params, batch):
            with sharding_context(mesh, rules):
                return step(params, batch)

        jitted = jax.jit(fn, in_shardings=(ns(params_ps), ns(batch_ps)))
        return jitted, (params_sds, batch_sds)

    # decode
    step = steps_mod.make_decode_step(model)
    cache_sds, cache_ps = steps_mod.cache_specs(model, shape, mesh, rules)

    def fn(params, cache, tokens, positions):
        with sharding_context(mesh, rules):
            return step(params, cache, tokens, positions)

    jitted = jax.jit(fn, in_shardings=(ns(params_ps), ns(cache_ps),
                                       ns(batch_ps["tokens"]),
                                       ns(batch_ps["positions"])),
                     donate_argnums=(1,))
    return jitted, (params_sds, cache_sds, batch_sds["tokens"],
                    batch_sds["positions"])


def apply_cfg_patch(cfg, patch: dict):
    """Apply {"field": v, "sub.field": v} overrides to a frozen config."""
    import dataclasses
    nested: dict = {}
    flat: dict = {}
    for key, val in patch.items():
        if "." in key:
            sub, field = key.split(".", 1)
            nested.setdefault(sub, {})[field] = val
        else:
            flat[key] = val
    for sub, fields in nested.items():
        flat[sub] = dataclasses.replace(getattr(cfg, sub), **fields)
    return dataclasses.replace(cfg, **flat)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             rules: AxisRules | None = None,
             pcfg: ParallelConfig | None = None, tag: str = "",
             cfg_patch: dict | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_patch:
        cfg = apply_cfg_patch(cfg, cfg_patch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    pcfg = pcfg or ParallelConfig()
    rules = rules or AxisRules()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    jitted, args = build_lowerable(cfg, shape, mesh, rules, pcfg)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    roof = ra.analyze(compiled, hlo, arch=arch, shape=shape_name,
                      mesh_name=mesh_name, chips=chips,
                      model_flops=ra.model_flops_estimate(cfg, shape))
    rec.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               memory_analysis=repr(mem), roofline=roof.to_dict())
    rec["fits_hbm"] = bool(roof.peak_mem_bytes <= ra.HBM_PER_CHIP)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    print(f"[cached] {arch} {shape_name} {mesh_name}: "
                          f"{rec.get('status')}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, multi_pod, out_dir)
                except Exception as e:  # noqa: BLE001 - record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                path.write_text(json.dumps(rec, indent=2, default=str))
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" t_c={r['t_compute']:.3e}s t_m={r['t_memory']:.3e}s"
                             f" t_coll={r['t_collective']:.3e}s"
                             f" bottleneck={r['bottleneck']}"
                             f" peak_mem={r['peak_mem_bytes']/2**30:.2f}GiB"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{status}] {arch} {shape_name} {mesh_name}{extra}",
                      flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
