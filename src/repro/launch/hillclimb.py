"""§Perf hillclimb driver: re-lower a dry-run cell under a named variant
(rule overrides + parallel config) and record the roofline delta.

    python -m repro.launch.hillclimb --cell falcon_train --variant A1_bf16
    python -m repro.launch.hillclimb --all

Variants are explicit, named hypotheses (EXPERIMENTS.md §Perf documents the
napkin math for each); results land in experiments/perf/<cell>__<variant>.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
from pathlib import Path

from repro.configs.base import ParallelConfig
from repro.launch.dryrun import run_cell
from repro.parallel.sharding import AxisRules

PERF_DIR = Path(__file__).resolve().parents[3] / "experiments" / "perf"

EP2D = (("expert", ("model", "data")),
        ("act_expert2", ("model", "data")),
        ("expert_embed", None),
        ("moe_group2", None))
SERVE_NO_FSDP = (("embed", None),)
# multi-pod EP-2D: experts over (model,data), dispatch groups over pods
EP2D_POD = (("expert", ("model", "data")),
            ("act_expert2", ("model", "data")),
            ("expert_embed", None),
            ("moe_group2", "pod"))

# cell -> (arch, shape, [(variant, rules-overrides, pcfg-kwargs, cfg-patch)])
CELLS = {
    "falcon_train": ("falcon_mamba_7b", "train_4k", [
        ("A1_bf16_residual", (), {}, {}),
        ("A2_bf16+micro8", (), {"microbatches": 8}, {}),
        ("A3_bf16+micro8+optbf16", (), {"microbatches": 8,
                                        "opt_state_dtype": "bfloat16"}, {}),
        ("A4_bf16+micro16", (), {"microbatches": 16}, {}),
        ("A5_scanbf16", (), {}, {"ssm.scan_dtype": "bfloat16"}),
        ("A6_scanbf16+micro8", (), {"microbatches": 8},
         {"ssm.scan_dtype": "bfloat16"}),
        ("A8_best@2pod", (), {"microbatches": 16}, {}),
        ("A9_micro8@2pod", (), {"microbatches": 8}, {}),
    ]),
    "dsv3_decode": ("deepseek_v3_671b", "decode_32k", [
        ("B1_no_fsdp", SERVE_NO_FSDP, {}, {}),
        ("B2_ep2d", EP2D, {}, {}),
        ("B3_no_fsdp+ep2d", SERVE_NO_FSDP + EP2D, {}, {}),
        ("B4_ep2d+grouped", EP2D, {}, {"_regroup": True}),
        ("B5_grouped_only", (), {}, {"_regroup": True}),
    ]),
    "dsv3_train": ("deepseek_v3_671b", "train_4k", [
        ("C1_ep2d", EP2D, {}, {}),
        ("C2_ep2d+micro8", EP2D, {"microbatches": 8}, {}),
        ("C3_ep2d+micro8+optbf16", EP2D, {"microbatches": 8,
                                          "opt_state_dtype": "bfloat16"}, {}),
        ("C4_micro8", (), {"microbatches": 8}, {}),
        # round 2: router-bf16 + sort-based dispatch are in the default code
        # path now; these re-measure with them active
        ("C5_fixes", (), {}, {}),
        ("C6_fixes+ep2d", EP2D, {}, {}),
        ("C7_fixes+ep2d+micro8+optbf16", EP2D,
         {"microbatches": 8, "opt_state_dtype": "bfloat16"}, {}),
        ("C8_best@2pod", EP2D,
         {"microbatches": 8, "opt_state_dtype": "bfloat16"}, {}),
        ("C9_ep2dpod@2pod", EP2D_POD,
         {"microbatches": 8, "opt_state_dtype": "bfloat16"}, {}),
        ("C10_default+micro8@2pod", (),
         {"microbatches": 8, "opt_state_dtype": "bfloat16"}, {}),
    ]),
    "yi_prefill": ("yi_9b", "prefill_32k", [
        # extra (beyond the required three): sequence-parallel activations
        ("P1_seq_over_model", (("seq", "model"),), {}, {}),
    ]),
    "dsv3_decode2": ("deepseek_v3_671b", "decode_32k", [
        ("B6_fixes", (), {}, {}),
        ("B7_fixes+ep2d", EP2D, {}, {}),
        ("B8_carry_cache", (), {}, {}),
        ("B9_carry_cache+ep2d", EP2D, {}, {}),
        ("B10_best@2pod", EP2D, {}, {}),
    ]),
}


def run_variant(cell: str, variant: str):
    arch, shape, variants = CELLS[cell]
    spec = dict((v, (r, p, c)) for v, r, p, c in variants)
    rules_over, pcfg_kw, cfg_patch = spec[variant]
    cfg_patch = {k: v for k, v in cfg_patch.items() if not k.startswith("_")}
    rules = AxisRules()
    for name, axes in rules_over:
        rules = rules.replacing(name, axes)
    pcfg = ParallelConfig(**pcfg_kw)
    rec = run_cell(arch, shape, multi_pod=variant.endswith("@2pod"),
                   out_dir=PERF_DIR, rules=rules, pcfg=pcfg, tag=variant,
                   cfg_patch=cfg_patch)
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    path = PERF_DIR / f"{cell}__{variant}.json"
    path.write_text(json.dumps(rec, indent=2, default=str))
    if rec.get("status") == "ok":
        r = rec["roofline"]
        print(f"[{cell}/{variant}] t_c={r['t_compute']:.3e} "
              f"t_m={r['t_memory']:.3e} t_coll={r['t_collective']:.3e} "
              f"bneck={r['bottleneck']} peak={r['peak_mem_bytes']/2**30:.1f}GiB "
              f"compile={rec['compile_s']}s", flush=True)
    else:
        print(f"[{cell}/{variant}] {rec.get('status')}: "
              f"{rec.get('error', '')[:200]}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    targets = []
    for cell, (_, _, variants) in CELLS.items():
        if args.cell and cell != args.cell:
            continue
        for v, *_ in variants:
            if args.variant and v != args.variant:
                continue
            targets.append((cell, v))
    for cell, v in targets:
        path = PERF_DIR / f"{cell}__{v}.json"
        if path.exists() and not args.force:
            print(f"[cached] {cell}/{v}")
            continue
        run_variant(cell, v)


if __name__ == "__main__":
    main()
