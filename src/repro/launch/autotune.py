"""Autotuner: sweep sharding-rule / parallel-config variants for a cell and
pick the best by roofline step time (subject to the HBM fit constraint).

    python -m repro.launch.autotune --arch yi_9b --shape train_4k
    python -m repro.launch.autotune --arch deepseek_v3_671b --shape decode_32k

This mechanizes the §Perf loop's outer search: the candidate set encodes
the levers that won during manual hillclimbing (EP layouts, microbatching,
optimizer dtype, sequence parallelism), and the tuner evaluates each by
lower+compile+roofline, never touching real devices. Winners are written to
experiments/autotune/<arch>__<shape>__<mesh>.json for launchers to consume.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
from pathlib import Path

from repro.configs import get_config
from repro.configs.base import ParallelConfig, SHAPES
from repro.launch.dryrun import run_cell
from repro.parallel.sharding import AxisRules

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "autotune"

EP2D = (("expert", ("model", "data")), ("act_expert2", ("model", "data")),
        ("expert_embed", None), ("moe_group2", None))
EP2D_POD = EP2D[:-1] + (("moe_group2", "pod"),)
SP = (("seq", "model"),)


def candidates(cfg, shape, multi_pod: bool):
    """(name, rule-overrides, pcfg) candidates appropriate for the cell."""
    cands = [("default", (), ParallelConfig())]
    if shape.kind == "train":
        for mu in (4, 8):
            # microbatches must keep per-shard batch >= 1
            if shape.global_batch % mu == 0:
                cands.append((f"micro{mu}", (),
                              ParallelConfig(microbatches=mu)))
        cands.append(("micro8+optbf16", (),
                      ParallelConfig(microbatches=8,
                                     opt_state_dtype="bfloat16")))
    if shape.kind == "prefill":
        cands.append(("seq_parallel", SP, ParallelConfig()))
    if cfg.is_moe and cfg.moe.num_experts >= 64:
        ep = EP2D_POD if multi_pod else EP2D
        cands.append(("ep2d", ep, ParallelConfig()))
        if shape.kind == "train":
            cands.append(("ep2d+micro8+optbf16", ep,
                          ParallelConfig(microbatches=8,
                                         opt_state_dtype="bfloat16")))
    return cands


def step_time(rec) -> float:
    r = rec["roofline"]
    return max(r["t_compute"], r["t_memory"], r["t_collective"])


def tune(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    results = []
    for name, rule_over, pcfg in candidates(cfg, shape, multi_pod):
        rules = AxisRules()
        for ln, ax in rule_over:
            rules = rules.replacing(ln, ax)
        rec = run_cell(arch, shape_name, multi_pod, OUT_DIR, rules=rules,
                       pcfg=pcfg, tag=f"autotune:{name}")
        if rec.get("status") != "ok":
            print(f"  [{name}] {rec.get('status')}", flush=True)
            continue
        results.append((name, rec))
        r = rec["roofline"]
        print(f"  [{name}] step={step_time(rec):.3f}s "
              f"peak={r['peak_mem_bytes']/2**30:.1f}GiB "
              f"bneck={r['bottleneck']}", flush=True)
    if not results:
        raise RuntimeError("no candidate compiled")
    # prefer fitting HBM, then minimize step time
    results.sort(key=lambda nr: (not nr[1]["fits_hbm"], step_time(nr[1])))
    best_name, best = results[0]
    summary = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "best": best_name,
        "best_step_s": step_time(best),
        "best_peak_gib": best["roofline"]["peak_mem_bytes"] / 2**30,
        "candidates": {n: {"step_s": step_time(r),
                           "peak_gib": r["roofline"]["peak_mem_bytes"] / 2**30,
                           "fits_hbm": r["fits_hbm"]}
                       for n, r in results},
    }
    out = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    out.write_text(json.dumps(summary, indent=2))
    print(f"[autotune] best for {arch}/{shape_name}@{mesh_name}: {best_name} "
          f"(step {summary['best_step_s']:.3f}s, "
          f"peak {summary['best_peak_gib']:.1f}GiB)")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    tune(args.arch, args.shape, args.multipod)


if __name__ == "__main__":
    main()
