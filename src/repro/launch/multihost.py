"""Multi-host launch entry for real pods (the production counterpart of the
dry-run's placeholder devices).

On a real v5e deployment each host runs:

    python -m repro.launch.multihost --arch yi_9b --shape train_4k \
        --coordinator $COORD_ADDR --num-processes $NPROC --process-id $RANK

Environment detection covers SLURM (srun) and TPU pod metadata; with
neither, flags are required. After jax.distributed.initialize, the SAME
mesh/sharding/step code as the dry-run executes — that equivalence is the
point of doing the dry-run against 512 placeholder devices: the lowered
program is identical, only the device backend changes.
"""
from __future__ import annotations

import argparse
import os


def detect_env() -> dict:
    """Coordinator/process topology from the scheduler environment."""
    if "SLURM_JOB_ID" in os.environ:
        nodes = os.environ.get("SLURM_STEP_NODELIST", "")
        first = nodes.split(",")[0].replace("[", "").split("-")[0]
        return {
            "coordinator": f"{first}:8476",
            "num_processes": int(os.environ.get("SLURM_NTASKS", "1")),
            "process_id": int(os.environ.get("SLURM_PROCID", "0")),
        }
    if "TPU_WORKER_HOSTNAMES" in os.environ:  # GKE/TPU-VM pod env
        hosts = os.environ["TPU_WORKER_HOSTNAMES"].split(",")
        return {
            "coordinator": f"{hosts[0]}:8476",
            "num_processes": len(hosts),
            "process_id": int(os.environ.get("TPU_WORKER_ID", "0")),
        }
    return {}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args(argv)

    env = detect_env()
    coordinator = args.coordinator or env.get("coordinator")
    nproc = args.num_processes or env.get("num_processes", 1)
    pid = args.process_id if args.process_id is not None else env.get(
        "process_id", 0)

    import jax
    if coordinator and nproc > 1:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=nproc, process_id=pid)
    print(f"[multihost] process {pid}/{nproc}: "
          f"{jax.local_device_count()} local / {jax.device_count()} global "
          f"devices")

    from repro.configs import get_config
    from repro.configs.base import ParallelConfig, SHAPES
    from repro.launch.dryrun import build_lowerable
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.sharding import AxisRules

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cfg = get_config(args.arch)
    jitted, sds = build_lowerable(cfg, SHAPES[args.shape], mesh, AxisRules(),
                                  ParallelConfig())
    with mesh:
        compiled = jitted.lower(*sds).compile()
    print(f"[multihost] compiled {args.arch}/{args.shape} on "
          f"{mesh.devices.size} chips; "
          f"peak/device="
          f"{compiled.memory_analysis().temp_size_in_bytes/2**30:.2f}GiB")
    # a real run would now loop train_step over the data pipeline exactly as
    # repro.launch.train does on the local mesh.
    return compiled


if __name__ == "__main__":
    main()
