"""Production mesh construction.

Functions, not module-level constants, so importing never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
*before* any jax import (see launch/dryrun.py); smoke tests and benchmarks
see the real (single) device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Best-effort mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    mp = model_parallel if n % model_parallel == 0 else 1
    return make_mesh((n // mp, mp), ("data", "model"))


def host_device_grid(mesh: Mesh) -> dict:
    """Telemetry: devices per axis (for launch scripts / logs)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))
