"""Production mesh construction.

Functions, not module-level constants, so importing never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
*before* any jax import (see launch/dryrun.py); smoke tests and benchmarks
see the real (single) device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 has explicit mesh axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: every mesh axis is implicitly Auto
    AxisType = None


def mesh_axis_types(n: int) -> dict:
    """Kwargs adding ``axis_types=(Auto,)*n`` where the jax version has it.

    jax 0.4.x meshes are Auto-only and reject the kwarg, so on those
    versions this is an empty dict — semantics are identical either way.
    """
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def compat_shard_map(f, mesh: Mesh, in_specs, out_specs, axis_names=None):
    """jax.shard_map across versions (no replication check, matching the
    repo's manual-collective kernels).

    axis_names — the *manual* axes (new-API meaning); None = all mesh axes.
    jax 0.4.x inverts the parameter (`auto` = the non-manual axes).
    """
    if hasattr(jax, "shard_map"):  # jax >= 0.5
        kwargs = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # jax 0.4.x partial-manual (`auto=`) hard-crashes XLA on CPU
    # (hlo_sharding_util IsManualSubgroup check), so fall back to fully
    # manual: with replicated (P()) specs over the extra axes — the only
    # shape our callers use — semantics are identical, at the cost of
    # replication over the would-be-auto axes.
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-less mesh for PartitionSpec resolution, across jax versions."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_types(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes, **mesh_axis_types(len(axes)))


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Best-effort mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    mp = model_parallel if n % model_parallel == 0 else 1
    return make_mesh((n // mp, mp), ("data", "model"))


def host_device_grid(mesh: Mesh) -> dict:
    """Telemetry: devices per axis (for launch scripts / logs)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))
