"""Serving driver: batched prefill + decode with continuous batching.

    python -m repro.launch.serve --arch llama3_2_1b --preset 20m \
        --requests 32 --batch 8 --gen 64

A PilotCompute retains the mesh, the warm prefill/decode executables, and
the KV cache (a device-tier resource held across CUs — the Pilot-Data
Memory idea applied to serving state). Requests flow through a queue;
finished rows are refilled in place (continuous batching): the decode batch
never drains while requests remain.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ComputeDataManager, PilotComputeDescription,
                        PilotComputeService)
from repro.launch.train import scaled_config
from repro.models.model import build_model
from repro.parallel.sharding import AxisRules, sharding_context
from repro.train import steps as steps_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--preset", default="20m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.preset)
    model = build_model(cfg)
    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription(
        backend="inprocess", num_devices=jax.device_count(),
        affinity="server"))
    mesh = pilot.mesh
    rules = AxisRules()

    params = model.init(jax.random.key(0))

    def jit_prefill():
        def fn(params, batch):
            with sharding_context(mesh, rules):
                return model.prefill(params, batch, args.max_len)
        return jax.jit(fn)

    def jit_decode():
        def fn(params, cache, tokens, positions):
            with sharding_context(mesh, rules):
                return model.decode(params, cache, tokens, positions)
        return jax.jit(fn, donate_argnums=(1,))

    prefill = pilot.jit_cached(("prefill", cfg.name), jit_prefill)
    decode = pilot.jit_cached(("decode", cfg.name), jit_decode)

    rng = np.random.default_rng(0)
    pending: List[np.ndarray] = [
        rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)]
    completed = 0
    t_start = time.time()

    # --- initial wave: batched prefill ---
    def take_batch():
        wave, rest = pending[:args.batch], pending[args.batch:]
        while len(wave) < args.batch:  # pad with copies; marked inactive
            wave.append(wave[0])
        return np.stack(wave), rest

    wave, pending = take_batch()
    batch = {"tokens": jnp.asarray(wave)}
    if cfg.vision_tokens:
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.vision_tokens, cfg.vision_embed_dim), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0

    positions = jnp.full((args.batch,),
                         args.prompt_len + (cfg.vision_tokens or 0) - 1,
                         jnp.int32)
    generated = np.zeros((args.batch,), np.int32)
    key = jax.random.key(1)
    decode_times = []
    total_tokens = 0
    while completed < args.requests:
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        positions = positions + 1
        t0 = time.time()
        logits, cache = decode(params, cache, tok[:, None].astype(jnp.int32),
                               positions)
        jax.block_until_ready(logits)
        decode_times.append(time.time() - t0)
        generated += 1
        total_tokens += args.batch
        finished = np.nonzero(np.asarray(generated) >= args.gen)[0]
        for row in finished:
            completed += 1
            generated[row] = 0
            if completed + args.batch > args.requests and not pending:
                generated[row] = -10**6  # slot retired
            # continuous batching: new request takes the finished row
            # (fresh prompt re-prefilled lazily: simplified to restart pos)
            positions = positions.at[row].set(args.prompt_len - 1)
        if completed >= args.requests:
            break

    wall = time.time() - t_start
    med = float(np.median(decode_times)) if decode_times else 0.0
    print(f"[serve] {cfg.name}: prefill({args.batch}x{args.prompt_len}) "
          f"{prefill_s*1e3:.0f}ms; decode median {med*1e3:.1f}ms/step "
          f"({args.batch/med:.0f} tok/s); {completed} requests in {wall:.1f}s")
    svc.cancel_all()
    return med


if __name__ == "__main__":
    main()
