"""Serving CLI: continuous-batching LM serving ON the pilot substrate.

    python -m repro.launch.serve --arch llama3_2_1b --preset 20m \
        --requests 32 --batch 8 --gen 64 --pilots 2

This used to be a standalone driver that ran *beside* the pilot system
(params and KV state in loop locals, no scheduler, no recovery) — and
its continuous-batching loop was broken: finished rows were never
refilled with pending prompts, and retired/padded rows kept sampling and
counting as served tokens.  It is now a thin CLI over
``repro.serving.ServingEngine`` (see that module): model shards and
KV-cache pages are tiered Pilot-Data partitions, requests route
replica-aware through the ``SchedulingPolicy``, each pilot runs its
decode loop as a long-lived resident task, and — with ``--supervise``
and a ``--checkpoint-dir`` — a pilot killed mid-stream has its in-flight
requests recovered from the durable tier.

Migration: all the old flags work unchanged; the old single-pilot
behavior is ``--pilots 1`` (the default).  Programmatic users of
``main()`` now get the engine's stats dict back instead of the median
decode-step time.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import PilotSession
from repro.launch.train import scaled_config
from repro.models.model import build_model
from repro.serving import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--preset", default="20m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--pilots", type=int, default=1,
                    help="serving replicas (pilots) in the session")
    ap.add_argument("--memory-gb", type=float, default=0.5,
                    help="managed memory per pilot (shard + page tiers)")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="KV-page flush granularity in generated tokens")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="durable tier for shards + KV pages (enables "
                         "recovery of in-flight requests)")
    ap.add_argument("--supervise", action="store_true",
                    help="self-healing session: quarantine/respawn dead "
                         "pilots mid-stream")
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.preset)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    with PilotSession(checkpoint_dir=args.checkpoint_dir,
                      supervise=args.supervise) as session:
        session.add_pilots(args.pilots, num_devices=jax.device_count(),
                           memory_gb=args.memory_gb, affinity="server")
        engine = ServingEngine(
            session, model, batch_size=args.batch, max_len=args.max_len,
            temperature=args.temperature, page_tokens=args.page_tokens)
        with engine:
            engine.deploy()
            t0 = time.perf_counter()
            reqs = [engine.submit(p, args.gen) for p in prompts]
            engine.drain(timeout=600)
            wall = time.perf_counter() - t0
            stats = engine.stats()
            for r in reqs:
                assert len(r.result()) == args.gen
        steps = max(1, stats["decode_steps"])
        print(f"[serve] {cfg.name}: {stats['completed']}/{args.requests} "
              f"requests on {args.pilots} pilot(s) in {wall:.1f}s; "
              f"{stats['tokens_served']} tokens "
              f"({stats['tokens_served'] / wall:.0f} tok/s, "
              f"{wall / steps * 1e3:.1f}ms/step), "
              f"p99 latency {stats['p99_latency_s'] * 1e3:.0f}ms, "
              f"refills={stats['refills']}, "
              f"recovered={stats['recovered_requests']}")
        return stats


if __name__ == "__main__":
    main()
