"""Blockwise int8 quantization for optimizer state / gradient compression.

Symmetric per-block scaling (block = flat groups of ``block_size``), the
layout 8-bit optimizers use in public literature (Dettmers et al.,
arXiv:2110.02861). Scales are float32; amortized cost ≈ 8 + 32/block bits
per element. QTensor is a registered pytree whose original shape is static
aux-data, so it passes through jit/scan/pjit transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    data: jax.Array          # int8 (n_blocks, block)
    scale: jax.Array         # float32 (n_blocks, 1)
    shape: Tuple[int, ...]   # original shape (static aux)

    def tree_flatten(self):
        return (self.data, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


def quantize(x: jax.Array, block_size: int = 256) -> QTensor:
    shape = tuple(x.shape)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block_size)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, shape)


def dequantize(q: QTensor) -> jax.Array:
    flat = (q.data.astype(jnp.float32) * q.scale).reshape(-1)
    n = int(np.prod(q.shape)) if q.shape else 1
    return flat[:n].reshape(q.shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LogQTensor:
    """Log-domain uint8 quantization for strictly-nonnegative tensors with
    huge dynamic range (Adam's second moment): linear int8 zeroes out small
    entries in a block whose max is large, exploding 1/sqrt(v) steps. Here
    the *multiplicative* error is bounded by exp((hi-lo)/254) per block."""
    data: jax.Array          # uint8 (n_blocks, block)
    lo: jax.Array            # float32 (n_blocks, 1) log-domain min
    hi: jax.Array            # float32 (n_blocks, 1) log-domain max
    shape: Tuple[int, ...]

    def tree_flatten(self):
        return (self.data, self.lo, self.hi), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux)


_LOG_EPS = 1e-30


def quantize_log(x: jax.Array, block_size: int = 256) -> LogQTensor:
    shape = tuple(x.shape)
    flat = jnp.log(jnp.maximum(x.astype(jnp.float32), _LOG_EPS)).reshape(-1)
    pad = (-flat.size) % block_size
    if pad:
        flat = jnp.pad(flat, (0, pad), constant_values=jnp.log(_LOG_EPS))
    blocks = flat.reshape(-1, block_size)
    lo = blocks.min(axis=-1, keepdims=True)
    hi = blocks.max(axis=-1, keepdims=True)
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.clip(jnp.round((blocks - lo) / span * 254), 0, 254).astype(jnp.uint8)
    return LogQTensor(q, lo, hi, shape)


def dequantize_log(q: LogQTensor) -> jax.Array:
    span = jnp.maximum(q.hi - q.lo, 1e-12)
    logs = q.data.astype(jnp.float32) / 254 * span + q.lo
    vals = jnp.where(logs <= jnp.log(_LOG_EPS) + 1e-6, 0.0, jnp.exp(logs))
    flat = vals.reshape(-1)
    n = int(np.prod(q.shape)) if q.shape else 1
    return flat[:n].reshape(q.shape)
