"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def warmup_cosine(step, cfg: TrainConfig):
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * cfg.learning_rate * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)
