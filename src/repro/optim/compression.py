"""Gradient compression for the low-bandwidth (inter-pod) reduction.

Error-feedback int8 allreduce (1-bit-Adam / EF-SGD family): each pod
quantizes (grad + residual) to blockwise int8, exchanges the int8 payload
with an all_gather over the pod axis (8x fewer wire bytes than an fp32
ring all-reduce at pod count 2), dequantizes + averages locally, and keeps
the quantization error as residual for the next step — unbiased in the
long run, bounded staleness.

Implemented with jax.shard_map manual over the pod axis only; the data and
model axes stay auto-sharded inside, so this composes with the train step's
pjit sharding untouched.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import compat_shard_map
from repro.optim.quant import QTensor, dequantize, quantize


def _compress_leaf(g: jax.Array, residual: jax.Array, axis: str,
                   block: int = 256):
    gf = g.astype(jnp.float32) + residual
    q = quantize(gf, block)
    deq = dequantize(q)
    new_residual = gf - deq
    # exchange int8 payload + scales across the pod axis
    data_all = jax.lax.all_gather(q.data, axis)        # (P, nb, blk) int8
    scale_all = jax.lax.all_gather(q.scale, axis)      # (P, nb, 1)
    p = data_all.shape[0]
    summed = jnp.sum(data_all.astype(jnp.float32) * scale_all, axis=0) / p
    flat = summed.reshape(-1)
    n = 1
    for s in q.shape:
        n *= s
    mean_g = flat[:n].reshape(q.shape)
    return mean_g.astype(g.dtype), new_residual


def compressed_pod_mean(grads, residuals, mesh: Mesh, axis: str = "pod",
                        block: int = 256):
    """Tree-wise EF-int8 mean over `axis`. grads already reduced over data
    (per-pod view); residuals: same-shape fp32 tree (carried in TrainState).
    Returns (mean_grads, new_residuals)."""
    if axis not in mesh.axis_names:
        return grads, residuals

    def prog(g_tree, r_tree):
        out = jax.tree.map(
            functools.partial(_compress_leaf, axis=axis, block=block),
            g_tree, r_tree)
        gs = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        rs = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return gs, rs

    # manual over the pod axis only; data/model stay auto-sharded inside
    manual = compat_shard_map(
        prog, mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        axis_names=frozenset({axis}))
    return manual(grads, residuals)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
