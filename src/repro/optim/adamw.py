"""AdamW with ZeRO-style sharded state and configurable state dtype.

State dtypes: float32 (default), bfloat16, or int8 (blockwise-quantized m/v,
8-bit-Adam style). Optimizer state inherits each parameter's PartitionSpec —
combined with the FSDP "embed"->data rule this is ZeRO-1: every data shard
owns 1/|data| of m/v. All math runs in fp32 regardless of storage dtype.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim.quant import (LogQTensor, QTensor, dequantize,
                               dequantize_log, quantize, quantize_log)


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def _store(x: jax.Array, dtype: str, second_moment: bool = False):
    if dtype == "int8":
        # m: signed symmetric int8; v: log-domain uint8 (v spans many orders
        # of magnitude inside one block — linear int8 zeroes small entries
        # and explodes 1/sqrt(v); log-domain bounds the multiplicative error)
        return quantize_log(x) if second_moment else quantize(x)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _load(x) -> jax.Array:
    if isinstance(x, LogQTensor):
        return dequantize_log(x)
    if isinstance(x, QTensor):
        return dequantize(x)
    return x.astype(jnp.float32)


def adamw_init(params, state_dtype: str = "float32") -> OptState:
    zm = lambda p: _store(jnp.zeros(p.shape, jnp.float32), state_dtype)
    zv = lambda p: _store(jnp.zeros(p.shape, jnp.float32), state_dtype,
                          second_moment=True)
    return OptState(
        m=jax.tree.map(zm, params),
        v=jax.tree.map(zv, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(grads, opt_state: OptState, params, lr: jax.Array,
                 cfg: TrainConfig, state_dtype: str = "float32"):
    count = opt_state.count + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    # global-norm clip (fp32)
    if cfg.grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.float32(0.0)
        scale = jnp.float32(1.0)

    def upd(g, m_q, v_q, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * _load(m_q) + (1 - b1) * g
        v = b2 * _load(v_q) + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + 1e-8)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return (new_p, _store(m, state_dtype),
                _store(v, state_dtype, second_moment=True))

    is_q = lambda x: isinstance(x, QTensor)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state.m)
    flat_v = treedef.flatten_up_to(opt_state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), gnorm
