"""Pallas TPU kernel: causal/windowed GQA flash attention (forward).

TPU adaptation of the IO-aware attention insight (FlashAttention): stream KV
blocks through VMEM while the (BQ, BK) score tile lives entirely on-chip;
online-softmax running max/sum and the output accumulator sit in VMEM
scratch, so HBM traffic is O(S*(d + d)) instead of O(S^2). Block shapes are
MXU-aligned (multiples of 128 on the contracting/lane dims).

Grid: (batch*kv_heads*group, q_blocks, kv_blocks), kv innermost and
sequential (scratch carries across it); q/batch dims parallel. GQA is
handled by the index map: program bh covers q head (kv_head, g) and loads
the kv_head's K/V block — no KV duplication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # block-level skip: fully-masked blocks contribute nothing
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + bq - 1
    if window:
        run &= (q_start - (k_start + bk - 1)) < window

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (BQ, H)
        k = k_ref[0].astype(jnp.float32)                 # (BK, H)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        rel = qpos - kpos
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= rel >= 0
        if window:
            mask &= rel < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                              # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == nk_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q (B,Sq,Nq,H); k,v (B,Skv,Nkv,H) -> (B,Sq,Nq,H). Self-attention."""
    b, sq, nq, h = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nqb, nkb = sq // bq, skv // bk

    # flatten heads into the leading grid dim: bh = ((b * nkv) + kh) * g + gi
    qf = q.reshape(b, sq, nkv * g, h).transpose(0, 2, 1, 3).reshape(
        b * nkv * g, sq, h)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, skv, h)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, skv, h)

    grid = (b * nkv * g, nqb, nkb)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=h ** -0.5, causal=causal,
                          window=window, bq=bq, bk=bk, nk_blocks=nkb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, h), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, h), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, h), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, h), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nkv * g, sq, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, h), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, nkv * g, sq, h).transpose(0, 2, 1, 3)
