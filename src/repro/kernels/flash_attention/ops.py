"""Dispatch wrapper for flash attention: backend + block-size selection."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       impl: str = "auto", block_q: int = 256,
                       block_k: int = 256):
    """impl: auto | pallas | interpret | ref"""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    sq, skv = q.shape[1], k.shape[1]
    while sq % block_q:
        block_q //= 2
    while skv % block_k:
        block_k //= 2
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=max(block_q, 1), block_k=max(block_k, 1),
                           interpret=(impl == "interpret"))
