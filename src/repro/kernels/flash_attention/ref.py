"""Pure-jnp oracle for causal/windowed GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q (B,Sq,Nq,H); k,v (B,Skv,Nkv,H); Nq % Nkv == 0. Self-attention
    positions (q row i attends kv cols <= i)."""
    b, sq, nq, h = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, sq, nkv, g, h)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (h ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    rel = qpos - kpos
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= rel >= 0
    if window:
        mask &= rel < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, nq, h).astype(q.dtype)
