"""Pallas TPU kernel: fused KMeans assignment + partial centroid sums.

The paper's KMeans map phase ("compute the closest centroid for each point")
is the analytics hot-spot (§4.3). TPU adaptation: the pairwise-distance
matrix is computed in its matmul form so the MXU does the heavy lifting,
and the one-hot partial-sum reduction is a second MXU matmul — the whole
map phase is two matmuls + a VPU argmin, fused in VMEM so the (BN, K)
distance block never touches HBM.

Grid: one program per point-block; centroids stay VMEM-resident across the
grid; partial sums/counts/sse accumulate in the revisited output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, sums_ref, counts_ref, sse_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        sse_ref[...] = jnp.zeros_like(sse_ref)

    x = x_ref[...].astype(jnp.float32)              # (BN, D)
    c = c_ref[...].astype(jnp.float32)              # (K, D)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d2 = x2 - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) + c2
    idx = jnp.argmin(d2, axis=1)                    # (BN,)
    k = c.shape[0]
    one_hot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
               == idx[:, None]).astype(jnp.float32)
    sums_ref[...] += jnp.dot(one_hot.T, x, preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(one_hot, axis=0, keepdims=True)
    best = jnp.min(d2, axis=1)
    sse_ref[...] += jnp.sum(best)[None, None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(points: jax.Array, centroids: jax.Array,
                  block_n: int = 1024, interpret: bool = True):
    """points (N,D), centroids (K,D) -> (sums (K,D), counts (K,), sse ()).

    N must be a multiple of block_n (ops.py pads). K*D and BN*K blocks must
    fit VMEM: defaults target (K<=4096, D<=512) at fp32.
    """
    n, d = points.shape
    k = centroids.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    sums, counts, sse = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(points, centroids)
    return sums, counts[0], sse[0, 0]
