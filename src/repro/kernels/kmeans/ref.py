"""Pure-jnp oracle for the KMeans assignment/partial-sum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(points: jax.Array, centroids: jax.Array):
    """points (N,D), centroids (K,D) -> (sums (K,D), counts (K,), sse ()).

    Matmul form of squared distance; fp32 accumulation.
    """
    x = points.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d2 = x2 - 2.0 * (x @ c.T) + c2
    idx = jnp.argmin(d2, axis=1)
    one_hot = jax.nn.one_hot(idx, c.shape[0], dtype=jnp.float32)
    sums = one_hot.T @ x
    counts = one_hot.sum(axis=0)
    sse = jnp.sum(jnp.take_along_axis(d2, idx[:, None], axis=1))
    return sums, counts, sse
