"""Dispatch wrapper for the KMeans kernel: padding + backend selection.

On TPU: pallas (compiled). Elsewhere: pallas interpret mode for validation,
or the jnp oracle (fastest on CPU) for production CPU paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kmeans.kmeans import kmeans_assign
from repro.kernels.kmeans.ref import kmeans_assign_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kmeans_assign_op(points: jax.Array, centroids: jax.Array,
                     block_n: int = 1024, impl: str = "auto"):
    """impl: auto | pallas | interpret | ref"""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return kmeans_assign_ref(points, centroids)
    n = points.shape[0]
    block_n = min(block_n, max(8, n))
    pad = (-n) % block_n
    if pad:
        # padded points live at centroid-argmin of real data; neutralize by
        # giving them +inf distance via a huge coordinate offset is unsafe —
        # instead pad then subtract their contribution exactly.
        pass
    if pad:
        pad_pts = jnp.zeros((pad, points.shape[1]), points.dtype)
        pts = jnp.concatenate([points, pad_pts], axis=0)
    else:
        pts = points
    sums, counts, sse = kmeans_assign(
        pts, centroids, block_n=block_n, interpret=(impl == "interpret"))
    if pad:
        zsums, zcounts, zsse = kmeans_assign_ref(
            jnp.zeros((pad, points.shape[1]), points.dtype), centroids)
        sums = sums - zsums
        counts = counts - zcounts
        sse = sse - zsse
    return sums, counts, sse
