"""Pure-jnp oracle for single-token decode attention against a positional
KV cache (the layout used by repro.models.attention.gqa_decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_pos: jax.Array, positions: jax.Array,
                         window: int = 0) -> jax.Array:
    """q (B,Nq,H); k/v_cache (B,Sc,Nkv,H); cache_pos (B,Sc) int32 (absolute
    position stored in each slot, -1 = empty); positions (B,) current pos.
    Returns (B,Nq,H)."""
    b, nq, h = q.shape
    nkv = k_cache.shape[2]
    g = nq // nkv
    qg = q.reshape(b, nkv, g, h)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (h ** -0.5)
    rel = positions[:, None] - cache_pos                      # (B,Sc)
    valid = (cache_pos >= 0) & (rel >= 0)
    if window:
        valid &= rel < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, nq, h).astype(q.dtype)
