"""Dispatch wrapper for the decode-attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention_op(q, k_cache, v_cache, cache_pos, positions, *,
                        window: int = 0, impl: str = "auto",
                        block_k: int = 512):
    """impl: auto | pallas | interpret | ref"""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, cache_pos, positions,
                                    window=window)
    sc = k_cache.shape[1]
    while sc % block_k:
        block_k //= 2
    return decode_attention(q, k_cache, v_cache, cache_pos, positions,
                            window=window, block_k=max(block_k, 1),
                            interpret=(impl == "interpret"))
