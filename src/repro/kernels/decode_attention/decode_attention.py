"""Pallas TPU kernel: single-token decode attention over a positional KV
cache (flash-decoding adapted to TPU).

GPU flash-decoding splits the KV length across SMs and combines partials;
the TPU adaptation streams KV blocks through a *sequential* grid dimension
with the online-softmax state (m, l, acc) resident in VMEM scratch — the
(1, BK) score tile never touches HBM, so per step the kernel reads exactly
cache + q once: the serving roofline floor. Validity comes from the cache's
stored-position array (slot semantics identical to models/attention.py:
pos >= 0, pos <= current, and optionally within the sliding window).

Grid: (B * Nkv * G, kv_blocks), kv sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: int, bk: int,
            nk_blocks: int, g: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (1, H)
    k = k_ref[0].astype(jnp.float32)                  # (BK, H)
    v = v_ref[0].astype(jnp.float32)
    cpos = cpos_ref[0]                                # (BK,)
    cur = pos_ref[0]                                  # scalar current position
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1,BK)
    rel = cur - cpos
    valid = (cpos >= 0) & (rel >= 0)
    if window:
        valid &= rel < window
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_pos: jax.Array, positions: jax.Array, *,
                     window: int = 0, block_k: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q (B,Nq,H); k/v_cache (B,Sc,Nkv,H); cache_pos (B,Sc); positions (B,)."""
    b, nq, h = q.shape
    sc, nkv = k_cache.shape[1], k_cache.shape[2]
    g = nq // nkv
    bk = min(block_k, sc)
    assert sc % bk == 0, (sc, bk)
    nkb = sc // bk

    qf = q.reshape(b * nkv * g, 1, h)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * nkv, sc, h)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * nkv, sc, h)
    # per-bh replicated scalars
    pos_f = jnp.repeat(positions, nkv * g).reshape(b * nkv * g, 1)
    cpos_f = jnp.repeat(cache_pos, nkv, axis=0).reshape(b * nkv, sc)

    grid = (b * nkv * g, nkb)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=h ** -0.5, window=window, bk=bk,
                          nk_blocks=nkb, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, 1, h), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, h), lambda bh, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, h), lambda bh, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, ki: (bh // g, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, h), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nkv * g, 1, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, h), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos_f, qf, kf, vf, cpos_f)
    return out.reshape(b, nq, h)
