"""Pallas TPU kernel: Mamba-1 selective scan, chunked recurrence.

TPU adaptation: the CUDA kernel's warp-parallel scan has no direct analogue;
instead the sequence is chunked so each grid step keeps a (Di_blk, N) state
in VMEM scratch and walks its chunk sequentially with VPU elementwise ops
(the (Di, N) lane layout matches the 8x128 VPU tile; N=16 packs the sublane
dim). The chunk axis is a sequential grid dimension — the state never
round-trips to HBM between chunks, which is the entire point.

Grid: (batch, di_blocks, chunks) with chunks innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
            h_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)                   # (Dblk, N)
    d_skip = d_ref[...].astype(jnp.float32)              # (1, Dblk)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)             # (Dblk,)
        dtt = dt_ref[0, t].astype(jnp.float32)           # (Dblk,)
        bt = b_ref[0, t].astype(jnp.float32)             # (N,)
        ct = c_ref[0, t].astype(jnp.float32)             # (N,)
        da = jnp.exp(dtt[:, None] * a)                   # (Dblk, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1)             # (Dblk,)
        y_ref[0, t] = (y + xt * d_skip[0]).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(x: jax.Array, dt: jax.Array, a: jax.Array,
                   b_ssm: jax.Array, c_ssm: jax.Array, d_skip: jax.Array,
                   block_d: int = 512, chunk: int = 256,
                   interpret: bool = True):
    """x, dt (B,S,Di); a (Di,N); b_ssm,c_ssm (B,S,N); d_skip (Di,).
    Returns (y (B,S,Di), h_end (B,Di,N))."""
    bsz, s, di = x.shape
    n = a.shape[-1]
    bd = min(block_d, di)
    ck = min(chunk, s)
    assert di % bd == 0 and s % ck == 0, (di, bd, s, ck)
    grid = (bsz, di // bd, s // ck)
    y, h_end = pl.pallas_call(
        functools.partial(_kernel, chunk=ck, n_chunks=s // ck),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((bd, n), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, ck, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, ck, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, bd), lambda b, d, c: (0, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd, n), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), x.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b_ssm, c_ssm, d_skip.reshape(1, di))
    return y, h_end
