"""Dispatch wrapper for the selective-scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.selective_scan.selective_scan import selective_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def selective_scan_op(x, dt, a, b_ssm, c_ssm, d_skip, *, impl: str = "auto",
                      block_d: int = 512, chunk: int = 256):
    """impl: auto | pallas | interpret | ref"""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return selective_scan_ref(x, dt, a, b_ssm, c_ssm, d_skip)
    di, s = x.shape[2], x.shape[1]
    while di % block_d:
        block_d //= 2
    while s % chunk:
        chunk //= 2
    return selective_scan(x, dt, a, b_ssm, c_ssm, d_skip,
                          block_d=max(block_d, 1), chunk=max(chunk, 1),
                          interpret=(impl == "interpret"))
