"""Pure-jnp oracle for the Mamba-1 selective scan (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                       b_ssm: jax.Array, c_ssm: jax.Array, d_skip: jax.Array,
                       h0: jax.Array | None = None):
    """x, dt (B,S,Di); a (Di,N); b_ssm, c_ssm (B,S,N); d_skip (Di,).
    Returns (y (B,S,Di), h_end (B,Di,N)). Plain sequential scan, fp32."""
    bsz, s, di = x.shape
    n = a.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_ssm.astype(jnp.float32)
    cf = c_ssm.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        da = jnp.exp(dtt[..., None] * a[None])           # (B,Di,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    h_end, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xf * d_skip
    return y.astype(x.dtype), h_end
