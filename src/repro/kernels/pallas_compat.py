"""jax-version compatibility for Pallas TPU kernels.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
kernels import the name from here so they run on both (the container ships
jax 0.4.37).
"""
from jax.experimental.pallas import tpu as pltpu

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:  # jax 0.4.x
    CompilerParams = pltpu.TPUCompilerParams
