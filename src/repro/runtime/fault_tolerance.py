"""Pilot-level fault tolerance: heartbeat, re-provision, restore, resume.

The paper's pilot model makes recovery structural: system-level allocation
(the pilot) and application progress (checkpoints in Pilot-Data's persistent
tier) are decoupled, so losing a pilot never loses work past the last
checkpoint. The ResilientRunner drives that loop:

  run step CUs on the active pilot
  -> pilot FAILED (heartbeat)  -> re-provision (same or degraded size)
  -> restore latest checkpoint with the new mesh's shardings (elastic)
  -> resume at the restored step

Since PR 7 the detect/replace half of that loop is the supervision
layer's (repro.core.supervisor): the runner holds a detect-only
``PilotSupervisor`` (auto_respawn=False — the RUNNER owns when to
re-provision, because it must restore checkpointed state before
resuming) and delegates the release+re-provision step to
``supervisor.replace_pilot``, so the same quarantine bookkeeping,
respawn telemetry, and failure-detector math back both the step-loop
recovery here and the self-healing ``PilotSession(supervise=True)``
path.  The public surface (``run``, ``recoveries`` of RecoveryEvent) is
unchanged.

On a real multi-pod deployment the same logic runs in the launcher process
per pod slice with jax.distributed; the simulated backend exercises every
path deterministically on one host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.manager import ComputeDataManager, PilotComputeService
from repro.core.pilot import (ComputeUnitDescription, PilotCompute,
                              PilotComputeDescription, State)
from repro.core.supervisor import PilotSupervisor


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    old_pilot: str
    new_pilot: str
    restored_step: int
    downtime_s: float


class ResilientRunner:
    """Drives a step function through pilots with checkpoint/restart."""

    def __init__(self, service: PilotComputeService,
                 pilot_desc: PilotComputeDescription,
                 ckpt: CheckpointManager,
                 checkpoint_every: int = 10,
                 max_recoveries: int = 3):
        self.service = service
        self.manager = ComputeDataManager(service)
        self.pilot_desc = pilot_desc
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_recoveries = max_recoveries
        self.pilot: Optional[PilotCompute] = None
        self.recoveries: list[RecoveryEvent] = []
        # detect/quarantine-only supervisor: the runner decides WHEN to
        # replace (it must restore state first), the supervisor supplies
        # the replace primitive + quarantine bookkeeping.  No monitor
        # thread is started — the step loop itself is the failure probe.
        self.supervisor = PilotSupervisor(
            compute=service, manager=self.manager, auto_respawn=False,
            max_respawns=max_recoveries)

    def _ensure_pilot(self) -> PilotCompute:
        if self.pilot is None or self.pilot.state != State.RUNNING:
            self.pilot = self.service.submit_pilot(self.pilot_desc)
        return self.pilot

    def _replace_pilot(self, dead: PilotCompute) -> PilotCompute:
        """Release the corpse and re-provision through the supervision
        layer (quarantine-during-replacement + respawn telemetry), with a
        direct re-provision fallback if the supervisor already handled
        this pilot id."""
        new = self.supervisor.replace_pilot(dead, desc=self.pilot_desc)
        if new is None:
            new = self.service.submit_pilot(self.pilot_desc)
        self.pilot = new
        return new

    def run(self, state, step_fn: Callable, num_steps: int,
            batch_fn: Callable[[int], Any],
            restore_fn: Optional[Callable] = None,
            start_step: int = 0):
        """step_fn(state, batch) -> (state, metrics); batch_fn(i) -> batch.

        restore_fn(like_state) -> (state, step): rebuild device state from the
        checkpoint (injected so the runner stays model-agnostic; the default
        reuses ``state`` as the structure template with no resharding).
        """
        step = start_step
        recoveries = 0
        metrics_log = []
        while step < num_steps:
            pilot = self._ensure_pilot()
            try:
                batch = batch_fn(step)
                desc = ComputeUnitDescription(
                    fn=step_fn, args=(state, batch), name=f"train-step-{step}")
                cu = self.manager.submit(desc)
                state, metrics = cu.future.result(timeout=600)
                metrics_log.append(metrics)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(step, state, blocking=False)
            except Exception:  # noqa: BLE001 - pilot/CU failure path
                recoveries += 1
                if recoveries > self.max_recoveries:
                    raise
                t0 = time.monotonic()
                old_id = pilot.id if pilot else "?"
                new_pilot = self._replace_pilot(pilot)
                if restore_fn is not None:
                    state, restored = restore_fn(state)
                else:
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        state, restored = self.ckpt.restore(state)
                    else:
                        restored = start_step
                self.recoveries.append(RecoveryEvent(
                    step=step, old_pilot=old_id, new_pilot=new_pilot.id,
                    restored_step=restored,
                    downtime_s=time.monotonic() - t0))
                step = restored
        self.ckpt.wait()
        return state, metrics_log
