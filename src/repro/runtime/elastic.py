"""Elastic mesh management: shrink/grow the device mesh, reshard state.

At 1000+ node scale the question is never *if* a slice disappears but how
cheaply the job re-forms. The paper's pilot model answers structurally
(allocation is a placeholder, re-acquirable); this module supplies the
mechanical half for JAX: given survivors, build the largest well-formed
(data, model) mesh, recompute every PartitionSpec through the same AxisRules
table, and device_put host state into the new placement. Model-parallel
degree is preserved when possible (weights reshard cheaply along data) and
reduced only when survivors < model_parallel.

Since PR 10 the grow/shrink half of that loop belongs to the elasticity
layer (``repro.core.autoscaler``): an ``ElasticController`` built with a
``session=`` holds a manual (non-monitoring) ``Autoscaler`` and delegates
``grow``/``shrink`` to its ``scale_out``/``scale_in`` — scale-in runs the
full drain protocol (quiesce, serving handoff, partition evacuation)
before the mesh re-forms over the survivors — mirroring how
``runtime/fault_tolerance.py`` delegates detect/replace to the PR-7
supervisor.  The mesh math (``plan_mesh``/``build_mesh``/
``reshard_state``) and the session-less controller surface are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import mesh_axis_types
from repro.parallel.sharding import AxisRules, resolve_pspec


@dataclasses.dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_devices: int


def plan_mesh(num_devices: int, model_parallel: int,
              axes: Tuple[str, ...] = ("data", "model")) -> MeshPlan:
    """Largest (data, model) grid over the survivors."""
    mp = min(model_parallel, num_devices)
    while num_devices % mp and mp > 1:
        mp -= 1
    dp = num_devices // mp
    used = dp * mp
    return MeshPlan(shape=(dp, mp), axes=axes,
                    dropped_devices=num_devices - used)


def build_mesh(devices: Sequence, plan: MeshPlan) -> Mesh:
    used = int(np.prod(plan.shape))
    arr = np.array(list(devices)[:used]).reshape(plan.shape)
    return Mesh(arr, plan.axes, **mesh_axis_types(len(plan.axes)))


def reshard_state(host_state, spec_tree, mesh: Mesh, rules: AxisRules):
    """host arrays + logical specs -> device arrays on the new mesh."""
    def put(spec, leaf):
        ps = resolve_pspec(spec.logical, spec.shape, mesh, rules)
        return jax.device_put(np.asarray(leaf), NamedSharding(mesh, ps))
    from repro.models.common import ParamSpec
    return jax.tree.map(put, spec_tree, host_state,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


class ElasticController:
    """Track live devices; rebuild mesh + shardings on membership change.

    Built bare (``ElasticController(mp)``) it is the pure mesh-math
    controller it always was.  Built with ``session=``, it additionally
    owns a manual ``repro.core.autoscaler.Autoscaler`` (no monitor
    thread — membership changes are the caller's verbs here) and gains
    ``grow``/``shrink``: fleet changes go through the autoscaler's
    provision/drain protocol, then the mesh re-forms over the live
    pilots' devices."""

    def __init__(self, model_parallel: int, rules: Optional[AxisRules] = None,
                 *, session=None, min_pilots: int = 1, max_pilots: int = 8,
                 **autoscaler_kwargs):
        self.model_parallel = model_parallel
        self.rules = rules or AxisRules()
        self.generation = 0
        self.mesh: Optional[Mesh] = None
        self.events: List[dict] = []
        self.session = session
        self.autoscaler = None
        if session is not None:
            from repro.core.autoscaler import Autoscaler
            self.autoscaler = Autoscaler(session, min_pilots=min_pilots,
                                         max_pilots=max_pilots,
                                         **autoscaler_kwargs)

    def form(self, devices: Sequence) -> Mesh:
        plan = plan_mesh(len(devices), self.model_parallel)
        self.mesh = build_mesh(devices, plan)
        self.generation += 1
        self.events.append({"generation": self.generation,
                            "devices": len(devices), "shape": plan.shape,
                            "dropped": plan.dropped_devices})
        return self.mesh

    def on_failure(self, surviving) -> Mesh:
        return self.form(surviving)

    def on_join(self, devices) -> Mesh:
        return self.form(devices)

    # -- session-backed elasticity (delegates to the autoscaler) ---------
    def _session_devices(self) -> List:
        """The live fleet's devices, deduped in provision order (pilots
        may share devices on an oversubscribed in-process backend)."""
        from repro.core.pilot import State
        seen, devs = set(), []
        for p in self.session.pilots:
            if p.state is not State.RUNNING or p.mesh is None:
                continue
            for d in p.mesh.devices.flat:
                if d.id not in seen:
                    seen.add(d.id)
                    devs.append(d)
        return devs

    def grow(self, n: int = 1) -> Mesh:
        """Scale the fleet out by up to `n` pilots and re-form the mesh
        over the enlarged fleet's devices."""
        if self.autoscaler is None:
            raise RuntimeError("ElasticController.grow needs session=")
        self.autoscaler.scale_out(n, reason="elastic.grow")
        return self.form(self._session_devices())

    def shrink(self, pilot=None) -> Mesh:
        """Drain one pilot out of the fleet (full scale-in protocol:
        quiesce, evacuate partitions, release) and re-form the mesh over
        the survivors."""
        if self.autoscaler is None:
            raise RuntimeError("ElasticController.shrink needs session=")
        self.autoscaler.scale_in(pilot, reason="elastic.shrink")
        return self.form(self._session_devices())

    def close(self) -> None:
        if self.autoscaler is not None:
            self.autoscaler.close()
