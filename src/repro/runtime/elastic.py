"""Elastic mesh management: shrink/grow the device mesh, reshard state.

At 1000+ node scale the question is never *if* a slice disappears but how
cheaply the job re-forms. The paper's pilot model answers structurally
(allocation is a placeholder, re-acquirable); this module supplies the
mechanical half for JAX: given survivors, build the largest well-formed
(data, model) mesh, recompute every PartitionSpec through the same AxisRules
table, and device_put host state into the new placement. Model-parallel
degree is preserved when possible (weights reshard cheaply along data) and
reduced only when survivors < model_parallel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import mesh_axis_types
from repro.parallel.sharding import AxisRules, resolve_pspec


@dataclasses.dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_devices: int


def plan_mesh(num_devices: int, model_parallel: int,
              axes: Tuple[str, ...] = ("data", "model")) -> MeshPlan:
    """Largest (data, model) grid over the survivors."""
    mp = min(model_parallel, num_devices)
    while num_devices % mp and mp > 1:
        mp -= 1
    dp = num_devices // mp
    used = dp * mp
    return MeshPlan(shape=(dp, mp), axes=axes,
                    dropped_devices=num_devices - used)


def build_mesh(devices: Sequence, plan: MeshPlan) -> Mesh:
    used = int(np.prod(plan.shape))
    arr = np.array(list(devices)[:used]).reshape(plan.shape)
    return Mesh(arr, plan.axes, **mesh_axis_types(len(plan.axes)))


def reshard_state(host_state, spec_tree, mesh: Mesh, rules: AxisRules):
    """host arrays + logical specs -> device arrays on the new mesh."""
    def put(spec, leaf):
        ps = resolve_pspec(spec.logical, spec.shape, mesh, rules)
        return jax.device_put(np.asarray(leaf), NamedSharding(mesh, ps))
    from repro.models.common import ParamSpec
    return jax.tree.map(put, spec_tree, host_state,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


class ElasticController:
    """Track live devices; rebuild mesh + shardings on membership change."""

    def __init__(self, model_parallel: int, rules: Optional[AxisRules] = None):
        self.model_parallel = model_parallel
        self.rules = rules or AxisRules()
        self.generation = 0
        self.mesh: Optional[Mesh] = None
        self.events: List[dict] = []

    def form(self, devices: Sequence) -> Mesh:
        plan = plan_mesh(len(devices), self.model_parallel)
        self.mesh = build_mesh(devices, plan)
        self.generation += 1
        self.events.append({"generation": self.generation,
                            "devices": len(devices), "shape": plan.shape,
                            "dropped": plan.dropped_devices})
        return self.mesh

    def on_failure(self, surviving) -> Mesh:
        return self.form(surviving)

    def on_join(self, devices) -> Mesh:
        return self.form(devices)
