"""Straggler detection + speculative re-execution over the Pilot layer.

Detection: robust z-score of CU latency against the running median (MAD).
Mitigation: speculative duplicate — when a CU overruns the straggler
threshold, resubmit it to the next-best pilot and take whichever finishes
first (the classic MapReduce backup-task trick, which the Pilot-Abstraction
makes trivial because CUs are idempotent descriptors).
"""
from __future__ import annotations

import statistics
import threading
import time
from concurrent.futures import FIRST_COMPLETED, wait
from typing import List, Optional

from repro.core.manager import ComputeDataManager
from repro.core.pilot import ComputeUnit, ComputeUnitDescription


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, min_samples: int = 5):
        self.durations: List[float] = []
        self.threshold = threshold
        self.min_samples = min_samples
        self._lock = threading.Lock()
        self.flagged: List[str] = []

    def record(self, cu: ComputeUnit):
        if cu.end_time and cu.start_time:
            with self._lock:
                self.durations.append(cu.end_time - cu.start_time)

    def cutoff(self) -> Optional[float]:
        with self._lock:
            if len(self.durations) < self.min_samples:
                return None
            med = statistics.median(self.durations)
            mad = statistics.median(abs(d - med) for d in self.durations)
        return med + self.threshold * max(mad, 0.05 * med, 1e-4)

    def is_straggling(self, cu: ComputeUnit, now: Optional[float] = None) -> bool:
        cut = self.cutoff()
        if cut is None or not cu.start_time or cu.end_time:
            return False
        if (now or time.monotonic()) - cu.start_time > cut:
            with self._lock:
                self.flagged.append(cu.id)
            return True
        return False


def run_speculative(manager: ComputeDataManager, desc: ComputeUnitDescription,
                    monitor: StragglerMonitor, poll: float = 0.01,
                    max_backups: int = 1, timeout: float = 120.0):
    """Run a CU with speculative backup on straggle. Returns (result, info)."""
    primary = manager.submit(desc)
    cus = [primary]
    backups = 0
    t0 = time.monotonic()
    while True:
        done = [c for c in cus if c.future.done()]
        for c in done:
            monitor.record(c)
            if c.future.exception() is None:
                return c.future.result(), {
                    "winner": c.id, "speculative": c is not primary,
                    "launched": len(cus)}
        if done and all(c.future.done() for c in cus):
            # every attempt failed -> surface the primary's error
            primary.future.result()
        if (backups < max_backups and monitor.is_straggling(primary)):
            # backup must land on a different pilot than the straggler
            cus.append(manager.submit(
                desc, exclude=frozenset({primary.pilot_id})))
            backups += 1
        if time.monotonic() - t0 > timeout:
            raise TimeoutError(f"CU {primary.id} timed out")
        time.sleep(poll)
