"""Deterministic fallback for the slice of the hypothesis API this suite uses.

The container image does not ship `hypothesis`; rather than skip four test
modules, conftest.py registers this module as `hypothesis` when the real
package is missing. It covers exactly what the suite imports — `given`,
`settings`, and the `sampled_from` / `booleans` / `integers` / `lists`
strategies — replacing property search with a fixed-seed sweep of
`max_examples` pseudo-random draws, so runs are reproducible and failures
report the falsifying example. If real hypothesis is ever installed it
takes precedence and this file is inert.
"""
from __future__ import annotations

import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


def integers(min_value, max_value):
    # hypothesis bounds are inclusive
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10):
    def _sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(_sample)


strategies = types.SimpleNamespace(
    sampled_from=sampled_from, booleans=booleans, integers=integers,
    lists=lists)


def settings(**kwargs):
    max_examples = kwargs.get("max_examples", 10)

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # The wrapper takes no parameters on purpose: pytest must not see the
        # drawn argument names and mistake them for fixtures (real hypothesis
        # hides them through its pytest plugin).
        def run():
            n = getattr(run, "_stub_max_examples", 10)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example {fn.__name__}({drawn!r})") from e
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return deco
