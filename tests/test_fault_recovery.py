"""Fault injection: pilot death mid-map_reduce and recovery through the
durable checkpoint tier.

The contract under test (ISSUE 4): losing a pilot loses only its volatile
tiers; partitions persisted to (or spilled into) the shared checkpoint
store survive, and the retry path — map_reduce re-binding failed groups,
or plain pilot-aware reads — restores them byte-identically instead of
erroring."""
import shutil

import numpy as np
import pytest

from repro.core import (ComputeDataManager, DataUnit,
                        PilotComputeDescription, PilotComputeService,
                        PilotDataService, TierManager, make_backend)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import FaultPolicy, SimulatedClusterBackend
from repro.core.mapreduce import map_reduce


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


def _home_du(tmp_path, name="duf", parts=6, rows=64):
    """A DU homed on a throw-away file store (rmtree = losing the original
    staging source, so recovery MUST come from the checkpoint tier)."""
    rng = np.random.default_rng(7)
    arr = rng.normal(size=(parts * rows, 4)).astype(np.float32)
    home = tmp_path / f"{name}-home"
    du = DataUnit.from_array(name, arr, parts,
                             {"file": make_backend("file", root=home)},
                             tier="file")
    return du, arr, home


def _attach_tm(pilot, device_budget=None):
    pilot.attach_tier_manager(TierManager(
        {"host": make_backend("host"), "device": make_backend("device")},
        {"device": device_budget}, promote_threshold=0))
    return pilot


def test_lose_volatile_keeps_only_checkpoint_residents(tmp_path):
    tm = TierManager({"checkpoint": make_backend("checkpoint",
                                                 root=tmp_path / "ck"),
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     {"device": 1024, "host": 1024}, promote_threshold=0)
    for i in range(6):
        tm.put(f"p{i}", np.full(256, i, np.float32), "device")
    spilled = set(tm.resident_keys("checkpoint"))
    assert spilled                          # pressure reached the floor
    lost = set(tm.lose_volatile())
    assert lost == {f"p{i}" for i in range(6)} - spilled
    for k in spilled:                       # durable survivors, intact
        assert tm.tier_of(k) == "checkpoint"
        np.testing.assert_array_equal(tm.get(k),
                                      np.full(256, int(k[1:]), np.float32))
    for k in lost:
        assert tm.tier_of(k) is None
    assert tm.usage("device") == 0 and tm.usage("host") == 0
    tm.close()


def test_pilot_loss_then_reads_restore_from_checkpoint(tmp_path, service):
    """Registry-level recovery, no scheduler: pilot dies (volatile wiped),
    the home store vanishes, and pilot-aware reads through a survivor
    still return byte-identical data via the checkpoint home."""
    pds = PilotDataService(checkpoint_dir=str(tmp_path / "ckhome"))
    a = _attach_tm(service.submit_pilot(
        PilotComputeDescription(backend="inprocess")))
    b = _attach_tm(service.submit_pilot(
        PilotComputeDescription(backend="inprocess")))
    pds.register_pilot(a)
    pds.register_pilot(b)
    du, arr, home = _home_du(tmp_path)
    pds.register(du, persist=True)
    pds.flush_checkpoints()                 # durability barrier
    du.replicate_to_pilot(a)                # a holds every replica
    shutil.rmtree(home)                     # original staging source gone
    a.tier_manager.lose_volatile()          # node death
    parts = np.array_split(arr, du.num_partitions, axis=0)
    for i in range(du.num_partitions):
        got = np.asarray(du.partition(i, pilot=b))
        np.testing.assert_array_equal(got, parts[i])
    assert pds.counters["checkpoint_restores"] >= du.num_partitions
    pds.close()


def test_map_reduce_retries_failed_group_onto_survivor(tmp_path, service):
    """Kill a pilot mid-map_reduce: its group CU fails, the engine
    re-binds the failed partitions onto the surviving pilot, and the
    result matches the no-failure reference; the recovered partitions are
    byte-identical, restored through the checkpoint tier."""
    register_backend(SimulatedClusterBackend(
        substrate="slurm",
        policy=FaultPolicy(fail_devices_at=0, lose_memory=True)))
    pds = PilotDataService(checkpoint_dir=str(tmp_path / "ckhome"))
    flaky = _attach_tm(service.submit_pilot(
        PilotComputeDescription(backend="simulated")))
    backup = _attach_tm(service.submit_pilot(
        PilotComputeDescription(backend="inprocess")))
    pds.register_pilot(flaky)
    pds.register_pilot(backup)
    manager = ComputeDataManager(service)

    du, arr, home = _home_du(tmp_path, parts=6)
    pds.register(du, persist=True)
    pds.flush_checkpoints()
    # replica placement routes half the groups to the doomed pilot
    du.replicate_to_pilot(flaky, parts=[0, 1, 2])
    du.replicate_to_pilot(backup, parts=[3, 4, 5])
    shutil.rmtree(home)                     # checkpoint is the only source

    reference = float(np.asarray(arr, np.float64).sum())
    total = map_reduce(du, lambda p: np.asarray(p, np.float64).sum(),
                       lambda x, y: x + y, manager=manager, jit_map=False,
                       retries=2)
    assert total == pytest.approx(reference, rel=1e-6)
    # the flaky pilot really did die and really did lose its memory
    assert flaky.state.value == "Failed"
    assert flaky.tier_manager.usage("device") == 0
    # recovery came through the durable store, byte-identically
    assert pds.counters["checkpoint_restores"] > 0
    parts = np.array_split(arr, du.num_partitions, axis=0)
    for i in range(du.num_partitions):
        np.testing.assert_array_equal(
            np.asarray(du.partition(i, pilot=backup)), parts[i])
    pds.close()


def test_map_reduce_raises_when_retries_exhausted(tmp_path, service):
    register_backend(SimulatedClusterBackend(
        substrate="slurm",
        policy=FaultPolicy(fail_devices_at=0, lose_memory=True)))
    pds = PilotDataService(checkpoint_dir=str(tmp_path / "ckhome"))
    flaky = _attach_tm(service.submit_pilot(
        PilotComputeDescription(backend="simulated")))
    pds.register_pilot(flaky)
    manager = ComputeDataManager(service)
    du, arr, home = _home_du(tmp_path, parts=2)
    pds.register(du, persist=True)
    with pytest.raises(RuntimeError, match="lost its devices"):
        map_reduce(du, lambda p: float(np.asarray(p).sum()),
                   lambda x, y: x + y, manager=manager, jit_map=False,
                   retries=1)
    pds.close()


def test_spilled_partitions_survive_pilot_death_without_persist(tmp_path,
                                                                service):
    """The spill path alone is a recovery path: partitions a pilot demoted
    into the shared checkpoint store under pressure (never explicitly
    persisted) survive its death and restore through the service."""
    store_dir = str(tmp_path / "spill-home")
    pds = PilotDataService(checkpoint_dir=store_dir)
    du, arr, home = _home_du(tmp_path, parts=4)
    part_bytes = du.nbytes() // 4
    # the pilot's volatile tiers hold ONE partition; the rest spill into
    # the shared durable store on replication
    a = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    a.attach_tier_manager(TierManager(
        {"checkpoint": make_backend("checkpoint", root=store_dir),
         "host": make_backend("host"), "device": make_backend("device")},
        {"device": part_bytes + part_bytes // 2, "host": part_bytes // 2},
        promote_threshold=0))
    b = _attach_tm(service.submit_pilot(
        PilotComputeDescription(backend="inprocess")))
    pds.register_pilot(a)
    pds.register_pilot(b)
    pds.register(du)
    du.replicate_to_pilot(a)                # overflow demotes to checkpoint
    spilled = [k for k in a.tier_manager.resident_keys("checkpoint")]
    assert spilled
    a.tier_manager.close()                  # flush spill writes, fsync
    shutil.rmtree(home)
    a.tier_manager.lose_volatile()
    pds.unregister_pilot(a.id)              # the pilot is fully gone
    parts = np.array_split(arr, du.num_partitions, axis=0)
    for i, key in enumerate(du._key(j) for j in range(4)):
        if key in spilled:
            np.testing.assert_array_equal(
                np.asarray(du.partition(i, pilot=b)), parts[i])
    assert pds.counters["checkpoint_restores"] >= len(spilled)
    pds.close()
