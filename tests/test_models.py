"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; prefill+decode consistency vs full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ParallelConfig, TrainConfig, reduced
from repro.models.common import param_count
from repro.models.model import build_model
from repro.train import steps as steps_mod


def _batch(cfg, m, b=2, s=16, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    st = m.token_seq_len(s)
    batch = {"tokens": jax.random.randint(ks[0], (b, st), 0, cfg.vocab_size)}
    if cfg.vision_tokens:
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (b, cfg.vision_tokens, cfg.vision_embed_dim), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = 0.1 * jax.random.normal(
            ks[2], (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg, m)
    out = m.train_forward(params, batch)
    logits = out["logits"]
    assert logits.shape == batch["tokens"].shape + (cfg.vocab_size,)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    if cfg.mtp_depth:
        assert out["mtp_logits"].shape == logits.shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_over_steps(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    m = build_model(cfg)
    pcfg, tcfg = ParallelConfig(), TrainConfig(learning_rate=5e-3,
                                               warmup_steps=2, total_steps=50)
    step = jax.jit(steps_mod.make_train_step(m, pcfg, tcfg))
    state = steps_mod.init_train_state(m, jax.random.key(0), pcfg)
    batch = _batch(cfg, m, b=4, s=32)
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert not any(np.isnan(l) for l in losses)
    assert losses[-1] < losses[0], losses  # memorizes a fixed batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:  # avoid capacity-drop divergence
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    b, s = 2, 16
    batch = _batch(cfg, m, b=b, s=s, key=1)
    tokens = batch["tokens"]
    pre = dict(batch)
    pre["tokens"] = tokens[:, :-1]
    _, cache = m.prefill(params, pre, max_len=32)
    pos = jnp.full((b,), tokens.shape[1] - 1 + (cfg.vision_tokens or 0),
                   jnp.int32)
    dec_logits, _ = m.decode(params, cache, tokens[:, -1:], pos)
    full = m.train_forward(params, batch)["logits"][:, -1]
    err = float(jnp.max(jnp.abs(dec_logits.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(full.astype(jnp.float32)))) + 1e-6
    assert err / scale < 0.05, (arch, err / scale)


def test_param_counts_match_analytic_order():
    """Spec-tree param count is within 2x of the config's analytic count
    for the full-size configs (catches missing/duplicated layers)."""
    from repro.models.transformer import model_specs
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n_spec = param_count(model_specs(cfg))
        n_analytic = cfg.num_params()
        ratio = n_spec / max(n_analytic, 1)
        assert 0.5 < ratio < 2.0, (arch, n_spec, n_analytic)


def test_full_config_sizes():
    """Headline parameter counts are in the right ballpark."""
    from repro.models.transformer import model_specs
    expect = {"deepseek_v3_671b": (600e9, 750e9),
              "mixtral_8x22b": (120e9, 150e9),
              "deepseek_67b": (60e9, 72e9),
              "falcon_mamba_7b": (6e9, 9e9),
              "yi_9b": (8e9, 10e9),
              "starcoder2_7b": (6e9, 8.5e9),
              "llama3_2_1b": (1.0e9, 1.6e9),
              "hymba_1_5b": (1.2e9, 2.2e9),
              "whisper_base": (0.05e9, 0.12e9)}
    for arch, (lo, hi) in expect.items():
        n = param_count(model_specs(get_config(arch)))
        assert lo <= n <= hi, (arch, n / 1e9)


def test_sliding_window_masks_differ():
    """Mixtral SWA: token far outside the window must not influence logits."""
    cfg = reduced(get_config("mixtral_8x22b"), sliding_window=8,
                  num_layers=1)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    t1 = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    l1 = m.train_forward(params, {"tokens": t1})["logits"][:, -1]
    l2 = m.train_forward(params, {"tokens": t2})["logits"][:, -1]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)
