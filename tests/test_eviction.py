"""Eviction policies: pluggability (LRU default, GDSF selectable), GDSF
cost/size/frequency ordering, restage-cost derivation from TierProfiles,
and hysteresis bounding demote/promote ping-pong under alternating access."""
import numpy as np
import pytest

from repro.core import (GDSFPolicy, LRUPolicy, TierManager, make_backend,
                        make_policy)
from repro.core.memory import PROFILES, FileBackend

KB = 1024


def _tm(tmp_path, device_budget=None, policy="lru", hysteresis=0,
        promote_threshold=0, file_profile=None):
    file_be = (FileBackend(tmp_path / "f", file_profile)
               if file_profile is not None
               else make_backend("file", root=tmp_path / "f"))
    backends = {"file": file_be, "host": make_backend("host"),
                "device": make_backend("device")}
    return TierManager(backends, {"device": device_budget}, policy=policy,
                       hysteresis=hysteresis,
                       promote_threshold=promote_threshold)


def _arr(kb, fill=0.0):
    return np.full((kb * KB) // 4, fill, np.float32)


def test_policy_pluggable_lru_default(tmp_path):
    tm = _tm(tmp_path)
    assert isinstance(tm.policy, LRUPolicy) and tm.policy.name == "lru"
    assert isinstance(_tm(tmp_path, policy="gdsf").policy, GDSFPolicy)
    custom = GDSFPolicy()
    assert _tm(tmp_path, policy=custom).policy is custom
    assert isinstance(make_policy("lru"), LRUPolicy)
    with pytest.raises(ValueError):
        make_policy("mru")
    with pytest.raises(ValueError):
        _tm(tmp_path, policy="nope")


@pytest.mark.parametrize("policy", ["lru", "gdsf"])
def test_policies_never_drop_data_or_exceed_budget(tmp_path, policy):
    tm = _tm(tmp_path, device_budget=4 * KB, policy=policy)
    for i in range(8):
        tm.put(f"p{i}", _arr(1, i), "device")
        assert tm.usage("device") <= 4 * KB
    for i in range(8):
        np.testing.assert_array_equal(tm.get(f"p{i}"), _arr(1, i))
    assert tm.peak_usage("device") <= 4 * KB


def _seed_small_hot_large_cold(tm):
    """4 small partitions (1 KB, read 3x) + one 4 KB partition touched most
    recently; then a second 4 KB insert forces an eviction decision."""
    for i in range(4):
        tm.put(f"s{i}", _arr(1, i), "device")
    tm.put("L1", _arr(4), "device")
    for _ in range(3):
        for i in range(4):
            tm.get(f"s{i}")
    tm.get("L1")                       # large is the most recent access
    tm.put("L2", _arr(4), "device")


def test_gdsf_keeps_small_hot_set_lru_does_not(tmp_path):
    budget = 8 * KB + KB // 2          # smalls + one large + slack
    gdsf = _tm(tmp_path / "g", device_budget=budget, policy="gdsf")
    _seed_small_hot_large_cold(gdsf)
    # frequency x cost / size: the recently-touched-but-cold-and-large L1
    # is evicted; the hot small set survives
    assert gdsf.tier_of("L1") == "host"
    for i in range(4):
        assert gdsf.tier_of(f"s{i}") == "device"
    # pure recency demotes the whole small hot set instead
    lru = _tm(tmp_path / "l", device_budget=budget, policy="lru")
    _seed_small_hot_large_cold(lru)
    assert lru.tier_of("L1") == "device"
    for i in range(4):
        assert lru.tier_of(f"s{i}") == "host"


def test_restage_cost_orders_by_size_and_profile(tmp_path):
    slow = _tm(tmp_path / "slow", file_profile=PROFILES["stampede_disk"])
    slow.put("small", _arr(64), "host")
    slow.put("big", _arr(512), "host")
    assert slow.restage_cost("big") > slow.restage_cost("small") > 0.0
    fast = _tm(tmp_path / "fast", file_profile=PROFILES["gordon_flash"])
    fast.put("small", _arr(64), "host")
    # same entry, slower colder tier -> strictly costlier to re-stage
    assert slow.restage_cost("small") > fast.restage_cost("small")


def test_gdsf_victim_is_cheapest_per_byte(tmp_path):
    tm = _tm(tmp_path, file_profile=PROFILES["stampede_disk"])
    tm.put("small", _arr(64), "host")
    tm.put("big", _arr(512), "host")
    pol = GDSFPolicy()
    entries = [tm._entries["small"], tm._entries["big"]]
    # equal frequency: the large partition has the lower priority density
    assert pol.priority(tm._entries["small"], tm) > pol.priority(
        tm._entries["big"], tm)
    assert pol.select_victim("host", entries, tm).key == "big"


def test_promotion_fires_at_threshold_despite_ledger_drains(tmp_path):
    """Non-promoting ledger drains (stats/_make_room) must not delay the
    heat-promotion decision past the threshold-th read."""
    tm = _tm(tmp_path, promote_threshold=4)
    tm.put("hot", _arr(1), "file")
    tm.get("hot")
    tm.get("hot")
    tm.stats()          # drains the ledger without evaluating promotion
    tm.get("hot")
    tm.get("hot")       # 4th read: decision must fire now, not at read 6
    tm.drain(timeout=10)
    assert tm.tier_of("hot") == "host"
    tm.close()


def test_gdsf_aging_evicts_long_idle_hot_entry(tmp_path):
    """Phase change: a once-hot entry must not squat on its lifetime
    frequency forever — L inflation outgrows its frozen priority."""
    tm = _tm(tmp_path, device_budget=2 * KB + KB // 2, policy="gdsf")
    tm.put("A", _arr(1, 7.0), "device")
    for _ in range(50):
        tm.get("A")                     # phase 1: A is very hot
    for i in range(20):                 # phase 2: A is never touched again
        tm.put(f"B{i}", _arr(1, i), "device")
        tm.get(f"B{i}")
        tm.get(f"B{i}")
    assert tm.tier_of("A") != "device"
    np.testing.assert_array_equal(tm.get("A"), _arr(1, 7.0))


def _ping_pong_cycles(tmp_path, hysteresis, rounds=10):
    """Alternating hot/cold access with room for only one of two
    partitions in the device tier; returns (promotes, demotes)."""
    tm = _tm(tmp_path, device_budget=KB + KB // 2, promote_threshold=1,
             hysteresis=hysteresis)
    tm.put("A", _arr(1, 1.0), "host")
    tm.put("B", _arr(1, 2.0), "host")
    try:
        for _ in range(rounds):
            tm.get("A")
            tm.drain(timeout=10)
            tm.get("B")
            tm.drain(timeout=10)
        promotes = sum(1 for e in tm.events if e["op"] == "promote")
        demotes = sum(1 for e in tm.events if e["op"] == "demote")
        # contents always intact regardless of churn
        np.testing.assert_array_equal(tm.get("A"), _arr(1, 1.0))
        np.testing.assert_array_equal(tm.get("B"), _arr(1, 2.0))
    finally:
        tm.close()
    return promotes, demotes


def test_hysteresis_bounds_demote_promote_ping_pong(tmp_path):
    rounds = 10
    promotes, demotes = _ping_pong_cycles(tmp_path / "h", hysteresis=100_000,
                                          rounds=rounds)
    # a demoted partition sits out re-promotion: one promotion per key plus
    # at most one displacement, instead of one cycle per access
    assert promotes <= 3
    assert demotes <= 2
    promotes0, _ = _ping_pong_cycles(tmp_path / "n", hysteresis=0,
                                     rounds=rounds)
    assert promotes0 >= 2 * rounds - 4      # unbounded ping-pong baseline
    assert promotes < promotes0
