"""Optimizer + quantization + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.quant import QTensor, dequantize, quantize


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2000),
       scale=st.sampled_from([1e-6, 1e-2, 1.0, 1e3]),
       block=st.sampled_from([32, 256]))
def test_quantize_roundtrip_error_bound(n, scale, block):
    x = scale * np.random.default_rng(n).normal(size=(n,)).astype(np.float32)
    q = quantize(jnp.asarray(x), block)
    back = np.asarray(dequantize(q))
    assert back.shape == x.shape
    # symmetric int8: error bounded by scale/127 per block (= max|block|/127)
    bound = np.abs(x).max() / 127 + 1e-12
    assert np.max(np.abs(back - x)) <= bound * 1.0001


def test_quantize_preserves_shape_tree_through_jit():
    x = jnp.arange(300, dtype=jnp.float32).reshape(10, 30)
    q = jax.jit(lambda t: quantize(t))(x)
    assert isinstance(q, QTensor) and q.shape == (10, 30)
    np.testing.assert_allclose(np.asarray(dequantize(q)), np.asarray(x),
                               atol=x.max() / 127 * 1.01)


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(state_dtype):
    """min ||w - target||^2 — every state dtype must converge."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=0.0)
    opt = adamw_init(params, state_dtype)
    for _ in range(120):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(grads, opt, params, 0.05, cfg,
                                      state_dtype)
    err = float(jnp.max(jnp.abs(params["w"] - target)))
    assert err < 0.05, (state_dtype, err)


def test_adamw_grad_clip_caps_update():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    cfg = TrainConfig(learning_rate=1.0, grad_clip=1.0, weight_decay=0.0)
    opt = adamw_init(params)
    _, _, gnorm = adamw_update({"w": jnp.full((4,), 100.0)}, opt, params,
                               1.0, cfg)
    assert float(gnorm) == pytest.approx(200.0)


def test_adamw_weight_decay_only_on_matrices():
    cfg = TrainConfig(learning_rate=0.1, weight_decay=1.0, grad_clip=0.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    opt = adamw_init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(zero_g, opt, params, 0.1, cfg)
    assert float(jnp.max(jnp.abs(new_p["b"] - 1.0))) < 1e-6  # no decay
    assert float(jnp.max(new_p["w"])) < 1.0                  # decayed


def test_int8_opt_state_memory_is_quarter():
    params = {"w": jnp.zeros((1024, 256), jnp.float32)}
    o32 = adamw_init(params, "float32")
    o8 = adamw_init(params, "int8")
    b32 = o32.m["w"].nbytes
    b8 = o8.m["w"].data.nbytes + o8.m["w"].scale.nbytes
    assert b8 < 0.30 * b32
