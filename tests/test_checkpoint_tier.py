"""The durable checkpoint tier: spill-under-pressure instead of refusal,
lazy restore with promotion, deterministic close (flush + fsync'd
manifest), reopen consistency, per-pilot provisioning knobs, the shared
store, and the 3x-over-budget acceptance workload."""
import json

import numpy as np
import pytest

from repro.core import (CapacityError, CheckpointBackend, DataUnit,
                        PilotComputeDescription, PilotComputeService,
                        TierManager, checkpoint_store, kmeans, make_backend,
                        make_blobs, make_tier_manager)

KB = 1024


def _arr(i, kb=1):
    return np.full((kb * KB // 4,), i, dtype=np.float32)


def _tm(tmp_path, device_budget=None, host_budget=None,
        promote_threshold=0, **kw):
    backends = {"checkpoint": make_backend("checkpoint",
                                           root=tmp_path / "ckpt"),
                "host": make_backend("host"),
                "device": make_backend("device")}
    return TierManager(backends,
                       {"device": device_budget, "host": host_budget},
                       promote_threshold=promote_threshold, **kw)


# -- spill + lazy restore ------------------------------------------------
def test_host_pressure_spills_to_checkpoint_instead_of_refusing(tmp_path):
    """Without the checkpoint tier a device+host hierarchy refuses once
    both budgets fill; with it, the coldest partitions spill to disk."""
    small = TierManager({"host": make_backend("host"),
                         "device": make_backend("device")},
                        {"device": 2 * KB, "host": 2 * KB},
                        promote_threshold=0)
    for i in range(4):
        small.put(f"p{i}", _arr(i), "device")
    with pytest.raises(CapacityError):
        small.put("p4", _arr(4), "device")

    tm = _tm(tmp_path, device_budget=2 * KB, host_budget=2 * KB)
    for i in range(8):
        tm.put(f"p{i}", _arr(i), "device")
        assert tm.usage("device") <= 2 * KB
        assert tm.usage("host") <= 2 * KB
    # the overflow went to the durable floor, nothing was dropped
    assert len(tm.resident_keys("checkpoint")) == 4
    for i in range(8):
        np.testing.assert_array_equal(tm.get(f"p{i}"), _arr(i))
    tm.close()


def test_lazy_restore_promotes_back_up_the_hierarchy(tmp_path):
    tm = _tm(tmp_path, device_budget=2 * KB, promote_threshold=2)
    for i in range(4):
        tm.put(f"p{i}", _arr(i), "device")
    spilled = tm.resident_keys("checkpoint") + tm.resident_keys("host")
    assert spilled                       # pressure pushed something down
    cold = spilled[0]
    for _ in range(6):                   # heat re-earns promotion
        np.testing.assert_array_equal(
            tm.get(cold), _arr(int(cold[1:])))
        tm.drain(timeout=10)
    assert tm.tier_of(cold) == "device"
    tm.close()


def test_checkpoint_budget_is_enforced(tmp_path):
    tm = TierManager({"checkpoint": make_backend("checkpoint",
                                                 root=tmp_path / "ck"),
                      "host": make_backend("host")},
                     {"host": 1 * KB, "checkpoint": 2 * KB},
                     promote_threshold=0)
    tm.put("a", _arr(1), "host")
    tm.put("b", _arr(2), "host")         # a -> checkpoint
    tm.put("c", _arr(3), "host")         # b -> checkpoint
    with pytest.raises(CapacityError):   # checkpoint full, coldest tier
        tm.put("d", _arr(4), "host")
    assert tm.usage("checkpoint") <= 2 * KB
    tm.close()


def test_promote_cost_bills_the_actual_tier(tmp_path):
    """A checkpoint-resident partition must price its restore at the
    persistent store's bandwidth, not the host tier's (the adaptive
    prefetch planner's seed)."""
    tm = _tm(tmp_path)
    tm.put("x", _arr(1, kb=64), "host")
    tm.stage("x", "checkpoint")
    from_ckpt = tm.promote_cost("x", "device")
    tm.stage("x", "host")
    from_host = tm.promote_cost("x", "device")
    assert from_ckpt > from_host
    assert tm.promote_cost("x", "host") == 0.0
    tm.close()


# -- deterministic close + reopen ---------------------------------------
def test_close_flushes_inflight_writes_and_fsyncs_manifest(tmp_path):
    tm = _tm(tmp_path, device_budget=2 * KB, host_budget=2 * KB)
    vals = {f"p{i}": _arr(i) for i in range(12)}
    for k, v in vals.items():
        tm.put(k, v, "device")           # spills ride the async writer
    spilled = tm.resident_keys("checkpoint")
    tm.close()
    # after close every spilled partition is ON DISK with a manifest entry
    manifest = json.loads((tmp_path / "ckpt" / "MANIFEST.json").read_text())
    assert set(spilled) <= set(manifest["keys"])
    for k in spilled:
        assert (tmp_path / "ckpt" / f"{k}.npy").exists()
    # no half-written temporaries survive the flush
    assert not list((tmp_path / "ckpt").rglob("*.tmp"))
    # a REOPENED store (fresh instance, manifest only) serves the bytes
    be = CheckpointBackend(tmp_path / "ckpt")
    assert set(be.keys()) == set(manifest["keys"])
    for k in spilled:
        np.testing.assert_array_equal(be.get(k), vals[k])


def test_reopened_manager_adopts_checkpointed_partitions(tmp_path):
    tm = _tm(tmp_path, host_budget=1 * KB)
    for i in range(3):
        tm.put(f"p{i}", _arr(i), "host")     # p0, p1 spill
    tm.close()
    # a NEW manager over the same directory sees a consistent store and
    # can adopt what the old one spilled
    tm2 = _tm(tmp_path)
    store = tm2.backends["checkpoint"]
    for k in store.keys():
        tm2.adopt(k, "checkpoint")
        np.testing.assert_array_equal(tm2.get(k), _arr(int(k[1:])))
    tm2.close()


def test_close_is_idempotent_and_store_stays_readable(tmp_path):
    tm = _tm(tmp_path, host_budget=1 * KB)
    tm.put("a", _arr(1), "host")
    tm.put("b", _arr(2), "host")
    tm.close()
    tm.close()
    np.testing.assert_array_equal(tm.get("a"), _arr(1))


def test_delete_leaves_no_orphan_checkpoint_files(tmp_path):
    tm = _tm(tmp_path, host_budget=1 * KB)
    for i in range(4):
        tm.put(f"p{i}", _arr(i), "host")
    for i in range(4):
        tm.delete(f"p{i}")
    tm.close()
    ck = tmp_path / "ckpt"
    assert not list(ck.rglob("*.npy"))
    assert json.loads((ck / "MANIFEST.json").read_text())["keys"] == {}


# -- sharing + pilot knobs ----------------------------------------------
def test_checkpoint_store_is_shared_per_directory(tmp_path):
    a = checkpoint_store(tmp_path / "shared")
    b = make_backend("checkpoint", root=tmp_path / "shared")
    assert a is b
    a.put("k", _arr(5))
    np.testing.assert_array_equal(b.get("k"), _arr(5))
    a.close()
    # a closed instance is replaced by a fresh reopen
    c = checkpoint_store(tmp_path / "shared")
    assert c is not a
    np.testing.assert_array_equal(c.get("k"), _arr(5))
    c.close()


def test_pilot_description_provisions_checkpoint_tier(tmp_path):
    svc = PilotComputeService()
    try:
        pilot = svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", memory_gb=0.25,
            checkpoint_dir=str(tmp_path / "pckpt"), checkpoint_gb=0.5))
        tm = pilot.tier_manager
        assert tm is not None
        assert tm.order[0] == "checkpoint"
        assert tm.budget("checkpoint") == int(0.5 * 2 ** 30)
        # two pilots naming the same dir share ONE store instance
        pilot2 = svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", memory_gb=0.25,
            checkpoint_dir=str(tmp_path / "pckpt")))
        assert (pilot2.tier_manager.backends["checkpoint"]
                is tm.backends["checkpoint"])
    finally:
        svc.cancel_all()


def test_simulated_backend_provisions_checkpoint_tier(tmp_path):
    from repro.core.backends.base import register_backend
    from repro.core.backends.simulated import SimulatedClusterBackend
    register_backend(SimulatedClusterBackend(substrate="slurm"))
    svc = PilotComputeService()
    try:
        pilot = svc.submit_pilot(PilotComputeDescription(
            backend="simulated", memory_gb=0.125,
            checkpoint_dir=str(tmp_path / "sim")))
        assert "checkpoint" in pilot.tier_manager.backends
    finally:
        svc.cancel_all()


# -- acceptance: 3x-over-budget working set ------------------------------
def test_kmeans_working_set_3x_budget_completes_with_checkpoint(tmp_path):
    """Device+host budgets hold only ~1/3 of the points; without a
    checkpoint tier the placement REFUSES, with one the run completes,
    budgets hold, and numerics match an unmanaged reference."""
    pts, _ = make_blobs(12_000, 8, d=8, seed=3)
    parts = 12
    part_bytes = pts.nbytes // parts
    device_budget = 3 * part_bytes + part_bytes // 2   # ~1/4 of the set
    host_budget = part_bytes + part_bytes // 2         # +1 partition

    small = TierManager({"host": make_backend("host"),
                         "device": make_backend("device")},
                        {"device": device_budget, "host": host_budget},
                        promote_threshold=0)
    with pytest.raises(CapacityError):
        DataUnit.from_array("toolarge", pts, parts, small.backends,
                            tier="device", tier_manager=small)

    tm = _tm(tmp_path, device_budget=device_budget,
             host_budget=host_budget, promote_threshold=2)
    du = DataUnit.from_array("pts3x", pts, parts, tm.backends,
                             tier="device", tier_manager=tm)
    assert du.resident_fraction("checkpoint") > 0     # real spill happened
    r = kmeans(du, k=8, iters=3, seed=0)
    tm.drain(timeout=30)
    assert tm.peak_usage("device") <= device_budget
    assert tm.peak_usage("host") <= host_budget
    backends = {"host": make_backend("host"),
                "device": make_backend("device")}
    du_ref = DataUnit.from_array("ref3x", pts, parts, backends, tier="host")
    r_ref = kmeans(du_ref, k=8, iters=3, seed=0)
    np.testing.assert_allclose(r.sse_history, r_ref.sse_history, rtol=1e-4)
    tm.close()
