"""ComputeDataManager scheduling against *measured* tier residency, late
binding timeout, and retry-after-pilot-failure."""
import numpy as np
import pytest

from repro.core import (ComputeDataManager, ComputeUnitDescription, DataUnit,
                        PilotComputeDescription, PilotComputeService,
                        TierManager, make_backend)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import FaultPolicy, SimulatedClusterBackend


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


def _managed_du(name, tmp_path, device_budget, parts=4):
    tm = TierManager({"host": make_backend("host"),
                      "device": make_backend("device")},
                     {"device": device_budget}, promote_threshold=0)
    arr = np.ones((parts * 256, 4), np.float32)
    du = DataUnit.from_array(name, arr, parts, tm.backends, tier="device",
                             tier_manager=tm)
    return du


def test_score_follows_actual_residency_not_nominal_tier(service, tmp_path):
    pilot = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    part_bytes = 256 * 4 * 4
    du_resident = _managed_du("res", tmp_path, device_budget=None)
    du_demoted = _managed_du("dem", tmp_path, device_budget=part_bytes)
    # both claim tier == 'device'; only one actually holds partitions there
    assert du_resident.tier == du_demoted.tier == "device"
    assert du_resident.resident_fraction("device") == 1.0
    assert du_demoted.resident_fraction("device") < 1.0
    s_res = manager.score(pilot, ComputeUnitDescription(
        fn=lambda: 0, input_data=(du_resident,)))
    s_dem = manager.score(pilot, ComputeUnitDescription(
        fn=lambda: 0, input_data=(du_demoted,)))
    assert s_res > s_dem
    # partial residency scores between fully-device and fully-host
    du_half = _managed_du("half", tmp_path, device_budget=2 * part_bytes)
    assert du_half.resident_fraction("device") == 0.5
    s_half = manager.score(pilot, ComputeUnitDescription(
        fn=lambda: 0, input_data=(du_half,)))
    assert s_res > s_half > manager.score(pilot, ComputeUnitDescription(
        fn=lambda: 0,
        input_data=(du_resident.to_tier("host"),)))


def test_select_pilot_timeout_raises(service):
    manager = ComputeDataManager(service)
    with pytest.raises(TimeoutError):
        manager.select_pilot(ComputeUnitDescription(fn=lambda: 0),
                             timeout=0.2)


def test_result_with_retry_resubmits_after_pilot_failure(service):
    register_backend(SimulatedClusterBackend(
        substrate="slurm", policy=FaultPolicy(fail_devices_at=0)))
    service.submit_pilot(PilotComputeDescription(backend="simulated"))
    service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    n_before = len(manager.history)
    out = manager.result_with_retry(
        ComputeUnitDescription(fn=lambda: "recovered"), retries=3)
    assert out == "recovered"
    # at least one resubmission happened
    assert len(manager.history) - n_before >= 2
