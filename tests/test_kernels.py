"""Per-kernel validation: interpret-mode pallas_call vs pure-jnp oracle,
with hypothesis sweeps over shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.kmeans.kmeans import kmeans_assign
from repro.kernels.kmeans.ops import kmeans_assign_op
from repro.kernels.kmeans.ref import kmeans_assign_ref
from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.selective_scan.selective_scan import selective_scan

SETTINGS = dict(max_examples=8, deadline=None)


# ---------------------------------------------------------------- kmeans ---
@settings(**SETTINGS)
@given(n=st.sampled_from([256, 512, 1000]),
       d=st.sampled_from([4, 8, 32]),
       k=st.sampled_from([5, 16, 64]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_kmeans_kernel_matches_ref(n, d, k, dtype):
    pts = jax.random.normal(jax.random.key(0), (n, d), dtype)
    cen = jax.random.normal(jax.random.key(1), (k, d), dtype)
    s1, c1, e1 = kmeans_assign_op(pts, cen, block_n=128, impl="interpret")
    s2, c2, e2 = kmeans_assign_ref(pts, cen)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=tol,
                               atol=tol * 10)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(float(e1), float(e2), rtol=tol)


def test_kmeans_counts_sum_to_n():
    pts = jax.random.normal(jax.random.key(2), (512, 8), jnp.float32)
    cen = jax.random.normal(jax.random.key(3), (16, 8), jnp.float32)
    _, counts, _ = kmeans_assign(pts, cen, block_n=128, interpret=True)
    assert int(counts.sum()) == 512


# ------------------------------------------------------------ flash attn ---
@settings(**SETTINGS)
@given(sq=st.sampled_from([128, 256, 384]),
       heads=st.sampled_from([(4, 2), (4, 4), (6, 3)]),
       h=st.sampled_from([32, 64]),
       causal=st.booleans(),
       window=st.sampled_from([0, 64]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_flash_attention_matches_ref(sq, heads, h, causal, window, dtype):
    nq, nkv = heads
    q = jax.random.normal(jax.random.key(0), (2, sq, nq, h), dtype)
    k = jax.random.normal(jax.random.key(1), (2, sq, nkv, h), dtype)
    v = jax.random.normal(jax.random.key(2), (2, sq, nkv, h), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_causality():
    """Perturbing a future token must not change past outputs."""
    q = jax.random.normal(jax.random.key(0), (1, 256, 4, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (1, 256, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (1, 256, 2, 32), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, interpret=True)
    k2 = k.at[0, -1].add(10.0)
    v2 = v.at[0, -1].add(10.0)
    o2 = flash_attention(q, k2, v2, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[:, :-1]), np.asarray(o2[:, :-1]),
                               atol=1e-6)


# --------------------------------------------------------- selective scan ---
@settings(**SETTINGS)
@given(s=st.sampled_from([64, 128, 192]),
       di=st.sampled_from([32, 64]),
       n=st.sampled_from([4, 16]),
       chunk=st.sampled_from([32, 64]))
def test_selective_scan_matches_ref(s, di, n, chunk):
    ks = jax.random.split(jax.random.key(0), 5)
    x = 0.5 * jax.random.normal(ks[0], (2, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, di)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[2], (di, n)))
    b = 0.5 * jax.random.normal(ks[3], (2, s, n))
    c = 0.5 * jax.random.normal(ks[4], (2, s, n))
    d = jnp.ones((di,))
    y1, h1 = selective_scan(x, dt, a, b, c, d, block_d=32, chunk=chunk,
                            interpret=True)
    y2, h2 = selective_scan_ref(x, dt, a, b, c, d)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)


def test_selective_scan_state_carry_equivalence():
    """Scanning [first half] then [second half with h0] == full scan
    (the prefill->decode handoff invariant)."""
    from repro.models.ssm import selective_scan as model_scan
    ks = jax.random.split(jax.random.key(7), 5)
    s, di, n = 128, 32, 8
    x = 0.5 * jax.random.normal(ks[0], (1, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, s, di)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[2], (di, n)))
    b = 0.5 * jax.random.normal(ks[3], (1, s, n))
    c = 0.5 * jax.random.normal(ks[4], (1, s, n))
    d = jnp.ones((di,))
    y_full, h_full = model_scan(x, dt, a, b, c, d, chunk=32)
    y1, h1 = model_scan(x[:, :64], dt[:, :64], a, b[:, :64], c[:, :64], d,
                        chunk=32)
    y2, h2 = model_scan(x[:, 64:], dt[:, 64:], a, b[:, 64:], c[:, 64:], d,
                        h0=h1, chunk=32)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------- decode attn ---
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@settings(**SETTINGS)
@given(sc=st.sampled_from([128, 256]),
       heads=st.sampled_from([(4, 2), (8, 2), (6, 3)]),
       h=st.sampled_from([32, 64]),
       window=st.sampled_from([0, 64]),
       fill_frac=st.sampled_from([0.25, 1.0]))
def test_decode_attention_matches_ref(sc, heads, h, window, fill_frac):
    nq, nkv = heads
    b = 2
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, nq, h), jnp.float32)
    kc = jax.random.normal(ks[1], (b, sc, nkv, h), jnp.float32)
    vc = jax.random.normal(ks[2], (b, sc, nkv, h), jnp.float32)
    fill = max(1, int(sc * fill_frac))
    cpos = jnp.where(jnp.arange(sc)[None] < fill, jnp.arange(sc)[None], -1)
    cpos = jnp.broadcast_to(cpos, (b, sc)).astype(jnp.int32)
    pos = jnp.full((b,), fill - 1, jnp.int32)
    out = decode_attention(q, kc, vc, cpos, pos, window=window, block_k=64,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, cpos, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_ignores_empty_slots():
    """Garbage in empty (-1) cache slots must not affect the output."""
    b, sc, nq, nkv, h = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, nq, h), jnp.float32)
    kc = jax.random.normal(ks[1], (b, sc, nkv, h), jnp.float32)
    vc = jax.random.normal(ks[2], (b, sc, nkv, h), jnp.float32)
    cpos = jnp.where(jnp.arange(sc)[None] < 40, jnp.arange(sc)[None], -1)
    cpos = jnp.broadcast_to(cpos, (b, sc)).astype(jnp.int32)
    pos = jnp.full((b,), 39, jnp.int32)
    o1 = decode_attention(q, kc, vc, cpos, pos, interpret=True, block_k=64)
    kc2 = kc.at[:, 40:].add(100.0)
    vc2 = vc.at[:, 40:].add(100.0)
    o2 = decode_attention(q, kc2, vc2, cpos, pos, interpret=True, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
