"""Data pipeline: corpus determinism, batch shapes, prefetch, staging."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.core import make_backend
from repro.data.pipeline import BatchPipeline, corpus_data_unit, synthesize_corpus


def test_corpus_deterministic_and_in_vocab():
    a = synthesize_corpus(1000, 10_000, seed=3)
    b = synthesize_corpus(1000, 10_000, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 1000
    c = synthesize_corpus(1000, 10_000, seed=4)
    assert not np.array_equal(a, c)


def test_corpus_has_learnable_structure():
    """Bigram-injected corpus: conditional entropy < unigram entropy."""
    corpus = synthesize_corpus(256, 200_000, seed=0)
    uni = np.bincount(corpus, minlength=256) / corpus.size
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    pairs = corpus[:-1].astype(np.int64) * 256 + corpus[1:]
    joint = np.bincount(pairs, minlength=256 * 256) / pairs.size
    h_joint = -(joint[joint > 0] * np.log(joint[joint > 0])).sum()
    h_cond = h_joint - h_uni
    assert h_cond < 0.8 * h_uni


@pytest.mark.parametrize("arch", ["llama3_2_1b", "internvl2_2b", "whisper_base"])
def test_batch_pipeline_shapes(arch, tmp_path):
    cfg = reduced(get_config(arch))
    backends = {"file": make_backend("file", root=tmp_path),
                "host": make_backend("host")}
    du = corpus_data_unit("c", cfg, num_tokens=200_000, backends=backends,
                          num_shards=4)
    du.to_tier("host", delete_source=False)
    pipe = BatchPipeline(du, cfg, batch=4, seq_len=64)
    for _ in range(3):
        b = next(pipe)
        assert b["tokens"].shape == (4, 64)
        assert b["labels"].shape == (4, 64)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
        if cfg.vision_tokens:
            assert b["patch_embeds"].shape == (4, cfg.vision_tokens,
                                               cfg.vision_embed_dim)
        if cfg.encoder_layers:
            assert b["frames"].shape == (4, cfg.encoder_seq_len, cfg.d_model)
    pipe.close()
