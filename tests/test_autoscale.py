"""Elasticity (PR 10): autoscaler scaling decisions, the scale-in drain
protocol, proactive rebalancing, and the supervisor/serving interactions.

The contracts under test:

  * LoadScalingPolicy hysteresis: one hot sample never scales, a
    sustained breach does, and scale-in needs a longer cold streak;
  * a draining pilot stops receiving work (`eligible`) but stays
    readable, and undrain restores it;
  * scale-out clones the fleet's own description, joins the new pilot to
    the data service, and records a decision carrying the signal values;
  * drain-then-release never loses a partition (hypothesis property:
    every partition registered before scale-in is byte-identical
    readable after, from a surviving replica or the checkpoint tier);
  * scale-in racing a chaos kill picks a DISTINCT victim and both
    recover (supervisor respawns the corpse, autoscaler releases its own
    pick cleanly);
  * a drained serving replica hands off its in-flight requests like a
    reaped one — byte-exact outputs, nothing re-adopted mid-drain;
  * the rebalancer moves partitions off a pressure-skewed donor through
    replicate-then-drop, prices every move, and never touches a
    quarantined pilot.
"""
import tempfile
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Autoscaler, InterconnectModel, Link,
                        LoadScalingPolicy, PilotSession, Rebalancer,
                        ScalingSignals)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import (ChaosEvent, ChaosPolicy,
                                           SimulatedClusterBackend)
from repro.core.pilot import State
from repro.serving import ServingEngine


# -- unit: policy hysteresis -------------------------------------------------
def test_load_policy_hysteresis_and_watermarks():
    pol = LoadScalingPolicy(scale_out_load=1.5, scale_in_load=0.25,
                            hysteresis=2, in_hysteresis=3)
    hot = ScalingSignals(n_pilots=1, queue_depth=6, workers=2, load=3.0)
    cold = ScalingSignals(n_pilots=2, queue_depth=0, workers=4, load=0.0)
    mid = ScalingSignals(n_pilots=2, queue_depth=2, workers=4, load=0.5)
    # one hot sample holds; the second fires
    assert pol.decide(hot)[0] == "hold"
    action, reason = pol.decide(hot)
    assert action == "out" and "load 3.00" in reason
    # a mid sample resets BOTH streaks
    assert pol.decide(mid)[0] == "hold"
    assert pol.decide(hot)[0] == "hold"       # streak restarted
    # scale-in needs in_hysteresis consecutive cold samples
    assert pol.decide(cold)[0] == "hold"
    assert pol.decide(cold)[0] == "hold"
    assert pol.decide(cold)[0] == "in"
    # tier pressure alone is a hot signal even with an empty queue
    squeezed = ScalingSignals(n_pilots=1, workers=2, tier_pressure=0.99)
    pol2 = LoadScalingPolicy(hysteresis=1)
    action, reason = pol2.decide(squeezed)
    assert action == "out" and "tier pressure" in reason
    # equal watermarks would oscillate: rejected at construction
    with pytest.raises(ValueError):
        LoadScalingPolicy(scale_out_load=1.0, scale_in_load=1.0)


# -- drain quiesces scheduling ----------------------------------------------
def test_draining_pilot_stops_receiving_work():
    with PilotSession() as s:
        a, b = s.add_pilots(2, memory_gb=0.05)
        pol = s.manager.policy
        pol.drain(a.id)
        assert set(p.id for p in pol.eligible([a, b])) == {b.id}
        # fails closed: all pilots draining/quarantined => empty, no
        # fallback onto the victim
        pol.quarantine(b.id)
        assert pol.eligible([a, b]) == []
        pol.undrain(a.id)
        pol.readmit(b.id)
        assert len(pol.eligible([a, b])) == 2
        # while draining, work routes around the victim but the victim
        # still finishes what it already accepted
        pol.drain(a.id)
        batch = s.submit_tasks([(lambda x: x + 1, (i,)) for i in range(8)])
        assert batch.results(timeout=30) == list(range(1, 9))
        pol.undrain(a.id)


# -- scale-out ---------------------------------------------------------------
def test_scale_out_clones_fleet_and_records_decision():
    with PilotSession() as s:
        s.add_pilots(1, memory_gb=0.05)
        a = Autoscaler(s, min_pilots=1, max_pilots=2)
        added = a.scale_out(reason="unit")
        assert len(added) == 1
        p = added[0]
        # the clone carries managed memory and joined the data service
        assert p.tier_manager is not None
        assert s.data_service.knows(p.id)
        # at max_pilots: rejected, and the rejection is itself a decision
        assert a.scale_out() == []
        actions = [d.action for d in a.decisions]
        assert actions == ["scale-out", "reject-out"]
        # every decision carries the signal snapshot that drove it
        assert all("n_pilots" in d.signals for d in a.decisions)
        stats = a.stats()
        assert stats["counters"]["scale_outs"] == 1
        assert stats["counters"]["rejects"] == 1


def test_scale_in_respects_min_pilots_floor():
    with PilotSession() as s:
        s.add_pilots(1, memory_gb=0.05)
        a = Autoscaler(s, min_pilots=1, max_pilots=4)
        assert a.scale_in() is None
        assert a.decisions[-1].action == "reject-in"
        assert len(s.pilots) == 1


def test_scale_in_never_picks_quarantined_pilot():
    with PilotSession() as s:
        pilots = s.add_pilots(3, memory_gb=0.05)
        sick = pilots[0]
        s.manager.policy.quarantine(sick.id)
        a = Autoscaler(s, min_pilots=1, max_pilots=4)
        victim = a.scale_in()
        assert victim is not None and victim.id != sick.id
        # the sick pilot is still provisioned, just quarantined
        assert sick.state is State.RUNNING


# -- property: drain-then-release never loses a partition --------------------
@settings(max_examples=6)
@given(parts=st.integers(min_value=2, max_value=5),
       replication=st.integers(min_value=0, max_value=2),
       persist=st.booleans(),
       load_victim=st.booleans())
def test_scale_in_never_loses_a_partition(parts, replication, persist,
                                          load_victim):
    """Every partition registered before scale-in must be byte-identical
    readable after — from a surviving replica or the checkpoint tier —
    across random replication/persistence/placement shapes."""
    rng = np.random.default_rng(parts * 10 + replication * 2 + persist)
    ref = rng.normal(size=(parts * 16, 3)).astype(np.float32)
    with tempfile.TemporaryDirectory() as ckpt:
        with PilotSession(checkpoint_dir=ckpt) as s:
            s.add_pilots(3, memory_gb=0.05, host_memory_gb=0.2)
            du = s.data("pts", ref, parts=parts, replication=replication,
                        persist=persist)
            a = Autoscaler(s, min_pilots=1, max_pilots=4)
            victim = None
            if load_victim:
                # pile every partition onto one pilot, then target it
                victim = s.pilots[0]
                s.data_service.replicate_to_pilot(du, victim.id,
                                                  tier="host")
            released = a.scale_in(victim)
            assert released is not None
            d = a.decisions[-1]
            assert d.action == "scale-in" and d.pilot == released.id
            assert d.detail["evacuated"].get("failed", 0) == 0
            # the audit: every partition byte-identical
            got = np.concatenate([np.asarray(du.partition(i))
                                  for i in range(parts)], axis=0)
            np.testing.assert_array_equal(got, ref)


# -- supervisor interaction: scale-in racing a chaos kill --------------------
def test_scale_in_racing_chaos_kill_picks_distinct_victim():
    register_backend(SimulatedClusterBackend(
        substrate="slurm",
        policy=ChaosPolicy(events=(ChaosEvent(at_s=0.15, action="kill"),),
                           target_index=0)))
    s = PilotSession(supervise=True,
                     supervisor_kwargs={"interval_s": 0.02,
                                        "min_heartbeat_s": 0.05})
    try:
        doomed = s.add_pilot(backend="simulated", startup_seconds=0.01,
                             memory_gb=0.05)
        s.add_pilots(2, backend="simulated", startup_seconds=0.01,
                     memory_gb=0.05)
        a = Autoscaler(s, min_pilots=1, max_pilots=4)
        # wait for the kill to land, then immediately race the scale-in
        # against the supervisor's detection/respawn
        deadline = time.monotonic() + 5.0
        while doomed.state is State.RUNNING:
            assert time.monotonic() < deadline, "chaos kill never fired"
            time.sleep(0.01)
        released = None
        deadline = time.monotonic() + 8.0
        while released is None and time.monotonic() < deadline:
            released = a.scale_in(reason="race")
        assert released is not None, "scale-in never completed"
        assert released.id != doomed.id     # distinct victims
        # both recover: the corpse is respawned by the supervisor, the
        # released pilot is NOT (deliberate releases are forgotten)
        deadline = time.monotonic() + 8.0
        while not s.supervisor.respawns:
            assert time.monotonic() < deadline, "kill never respawned"
            time.sleep(0.02)
        assert s.supervisor.respawns[0].old_pilot == doomed.id
        time.sleep(0.2)     # give the monitor a chance to misfire
        assert all(ev.old_pilot != released.id
                   for ev in s.supervisor.respawns)
        running = [p for p in s.pilots if p.state is State.RUNNING]
        assert len(running) == 2            # 3 - killed - released + respawn
    finally:
        s.close()


# -- serving: drained replicas hand off like reaped ones ---------------------
class _StubModel:
    """next = (last + 1) % vocab (same exact-token stub as test_serving)."""

    def __init__(self, vocab=32, delay=0.0):
        self.cfg = SimpleNamespace(name="stub", vocab_size=vocab,
                                   vision_tokens=0, encoder_layers=0)
        self.vocab = vocab
        self.delay = delay

    def init(self, key):
        return {"w": jnp.zeros((4,), jnp.float32)}

    def _step(self, last):
        logits = jax.nn.one_hot((last + 1) % self.vocab, self.vocab) * 100.0
        return logits, {"last": last.astype(jnp.int32).reshape(-1, 1)}

    def _sleep(self):
        time.sleep(self.delay)
        return np.int32(0)

    def prefill(self, params, batch, max_len):
        return self._step(batch["tokens"][:, -1])

    def decode(self, params, cache, tokens, positions):
        tok = tokens[:, 0]
        if self.delay:
            pause = jax.experimental.io_callback(
                self._sleep, jax.ShapeDtypeStruct((), jnp.int32),
                ordered=True)
            tok = tok + pause
        return self._step(tok)


def _expected(prompt, gen, vocab=32):
    return [(int(prompt[-1]) + 1 + i) % vocab for i in range(gen)]


def test_serving_drain_replica_hands_off_in_flight_requests():
    model = _StubModel(delay=0.02)      # slow decode: drain lands mid-run
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 32, size=5).astype(np.int32)
               for _ in range(4)]
    with tempfile.TemporaryDirectory() as ckpt:
        with PilotSession(checkpoint_dir=ckpt) as s:
            pilots = s.add_pilots(2, memory_gb=0.25)
            with ServingEngine(s, model, batch_size=2, max_len=32,
                               page_tokens=2) as eng:
                eng.deploy(reaper_interval_s=0.02)
                assert eng in s.serving_engines
                reqs = [eng.submit(p, 6) for p in prompts]
                time.sleep(0.08)        # let decode start on both replicas
                # the autoscaler's handoff order: mark draining FIRST so
                # the reaper cannot instantly re-adopt the live pilot
                s.manager.policy.drain(pilots[0].id)
                eng.drain_replica(pilots[0].id)
                eng.drain(timeout=60)
                for p, r in zip(prompts, reqs):
                    assert r.result(timeout=5) == _expected(p, 6)
                st_ = eng.stats()
                assert st_["drained_replicas"] == 1
                assert pilots[0].id not in st_["replicas"]
                s.manager.policy.undrain(pilots[0].id)
            assert eng not in s.serving_engines     # close deregisters


# -- session wiring ----------------------------------------------------------
def test_session_autoscale_stats_surface():
    s = PilotSession(autoscale=True, min_pilots=1, max_pilots=3,
                     autoscaler_kwargs={"interval_s": 0.02},
                     rebalance=True,
                     rebalancer_kwargs={"interval_s": 0.05})
    try:
        s.add_pilots(1, memory_gb=0.05)
        assert s.autoscaler is not None and s.rebalancer is not None
        time.sleep(0.1)                 # a few monitor ticks
        stats = s.stats()
        assert stats["autoscaler"]["min_pilots"] == 1
        assert stats["autoscaler"]["counters"]["ticks"] >= 1
        assert "counters" in stats["rebalancer"]
    finally:
        s.close()
    # idempotent, and the loops are stopped
    s.close()


# -- rebalancer --------------------------------------------------------------
def test_rebalancer_moves_skew_priced_and_avoids_quarantined():
    ic = InterconnectModel(default=Link(gbps=10.0, latency_s=1e-4))
    with PilotSession(interconnect=ic) as s:
        pilots = s.add_pilots(3, memory_gb=0.05, host_memory_gb=0.2)
        donor, receiver, sick = pilots
        rng = np.random.default_rng(11)
        ref = rng.normal(size=(96, 4)).astype(np.float32)
        du = s.data("pts", ref, parts=6)
        # pile every partition onto one pilot => maximal skew
        s.data_service.replicate_to_pilot(du, donor.id, tier="host")
        # the third pilot is quarantined: never a donor OR receiver
        s.manager.policy.quarantine(sick.id)
        s.data_service.avoid_pilot(sick.id)
        r = Rebalancer(s, skew=1.2, max_moves=4)
        done = [m for m in r.rebalance_once() if m.status == "done"]
        assert done, "no migration executed"
        for m in done:
            assert m.src == donor.id
            assert m.dst == receiver.id         # never the quarantined one
            assert m.cost_s > 0.0               # priced by the interconnect
            assert m.nbytes > 0
        stats = r.stats()
        assert stats["counters"]["migrations"] == len(done)
        assert stats["counters"]["bytes_moved"] == sum(m.nbytes
                                                       for m in done)
        # data intact after the moves
        got = np.concatenate([np.asarray(du.partition(i))
                              for i in range(6)], axis=0)
        np.testing.assert_array_equal(got, ref)


def test_rebalancer_noop_when_balanced():
    with PilotSession() as s:
        s.add_pilots(2, memory_gb=0.05)
        r = Rebalancer(s)
        assert r.plan() == []
        assert r.rebalance_once() == []
