"""End-to-end behaviour tests: the full train/serve stack over the Pilot
layer (paper's system + the framework around it)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import main as train_main, scaled_config
from repro.launch.serve import main as serve_main


def test_train_loss_decreases(tmp_path):
    """Tiny LM, 60 steps on the real pipeline: loss must drop measurably
    below the corpus' unigram entropy (the bigram structure is learnable)."""
    final = train_main([
        "--arch", "llama3_2_1b", "--preset", "smoke", "--steps", "60",
        "--batch", "8", "--seq", "64", "--lr", "2e-2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "50",
        "--log-every", "50"])
    assert final < 5.2, final  # ln(512)=6.24 unigram ~5.6; must beat unigram


def test_train_recovers_from_injected_failure(tmp_path):
    final = train_main([
        "--arch", "llama3_2_1b", "--preset", "smoke", "--steps", "30",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "10", "--failure-at", "15", "--log-every", "100"])
    assert np.isfinite(final)
    # checkpoint dir has the final step
    from repro.checkpoint.checkpoint import CheckpointManager
    cfg = scaled_config("llama3_2_1b", "smoke")
    ckpt = CheckpointManager(Path(tmp_path) / cfg.name)
    assert ckpt.latest_step() == 30


def test_train_microbatched_matches_shapes(tmp_path):
    final = train_main([
        "--arch", "llama3_2_1b", "--preset", "smoke", "--steps", "6",
        "--batch", "8", "--seq", "32", "--microbatches", "2",
        "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    assert np.isfinite(final)


def test_train_int8_opt_state(tmp_path):
    final = train_main([
        "--arch", "llama3_2_1b", "--preset", "smoke", "--steps", "6",
        "--batch", "4", "--seq", "32", "--opt-dtype", "int8",
        "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    assert np.isfinite(final)


@pytest.mark.parametrize("arch", ["falcon_mamba_7b", "mixtral_8x22b",
                                  "whisper_base"])
def test_train_other_families_smoke(arch, tmp_path):
    final = train_main([
        "--arch", arch, "--preset", "smoke", "--steps", "4",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--log-every", "100"])
    assert np.isfinite(final)


def test_serve_end_to_end():
    stats = serve_main([
        "--arch", "llama3_2_1b", "--preset", "smoke", "--requests", "6",
        "--batch", "3", "--prompt-len", "8", "--gen", "8",
        "--max-len", "32"])
    assert stats["completed"] == 6
    assert stats["tokens_served"] == 6 * 8  # exact: no phantom row tokens
    assert stats["decode_steps"] > 0
