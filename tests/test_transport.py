"""The zero-copy data plane: Buf views, the codec registry, counters.

Covers the PR 8 transport contract end to end:

  * backend reads are read-only views (mmap'd FileBackend, aliasing
    host views, dlpack device views) and `copy_mode()` flips the same
    plane into materialize-always reads;
  * the codec registry: raw fast path vs pickle tail, header-only
    sizing, pluggable custom codecs;
  * provenance-carrying reads (`TierManager.get_buf`,
    `DataUnit.partition_buf`) and the sanctioned mutation path
    (`Buf.copy()` / `DataUnit.partition_copy`);
  * view stability across the moves that used to memcpy: demotion,
    overwrite, delete, cross-pilot replication/repair;
  * the `bytes_viewed`/`bytes_copied`/codec counters surfaced through
    `session.stats()["transport"]`.
"""
import shutil
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Buf, DataUnit, PilotDataService, PilotSession,
                        TRANSPORT_STATS, copy_mode, decode_file, encoder_for,
                        file_nbytes, make_backend, make_tier_manager,
                        read_partition, register_codec, unregister_codec)
from repro.core.buf import as_view, materialize, zero_copy_enabled
from repro.core.codecs import Codec, PickleCodec, RawCodec


@pytest.fixture()
def tmpdir():
    d = Path(tempfile.mkdtemp(prefix="transport_"))
    yield d
    shutil.rmtree(d, ignore_errors=True)


# -- Buf / view primitives ------------------------------------------------
def test_as_view_is_readonly_alias():
    a = np.arange(10.0)
    v = as_view(a)
    assert v.base is a and not v.flags.writeable
    a[0] = 42.0                         # the caller's array is untouched
    assert v[0] == 42.0                 # ... and the view aliases it
    with pytest.raises(ValueError):
        v[0] = 0.0


def test_materialize_is_owned_and_writable():
    a = np.arange(10.0)
    m = materialize(a)
    assert m.base is None and m.flags.writeable
    m[0] = -1.0
    assert a[0] == 0.0


def test_buf_surface():
    a = np.arange(6.0).reshape(2, 3)
    b = Buf(as_view(a), source="host")
    assert b.shape == (2, 3) and b.dtype == a.dtype and b.nbytes == a.nbytes
    assert len(b) == 2
    np.testing.assert_array_equal(np.asarray(b), a)
    assert not b.view().flags.writeable
    c = b.copy()
    assert c.flags.writeable and c.base is None
    c[0, 0] = 99.0
    assert a[0, 0] == 0.0
    assert "view" in repr(b)


def test_copy_mode_flips_and_restores():
    assert zero_copy_enabled()
    with copy_mode():
        assert not zero_copy_enabled()
        with copy_mode():
            assert not zero_copy_enabled()
        assert not zero_copy_enabled()
    assert zero_copy_enabled()


# -- codec registry -------------------------------------------------------
def test_raw_codec_fast_path_and_header_nbytes(tmpdir):
    arr = np.arange(1000, dtype=np.int64)
    path = tmpdir / "a.npy"
    with open(path, "wb") as f:
        encoder_for(arr).write(f, arr)
    assert isinstance(encoder_for(arr), RawCodec)
    out = decode_file(path)
    assert isinstance(out, np.memmap) and not out.flags.writeable
    np.testing.assert_array_equal(out, arr)
    assert file_nbytes(path) == arr.nbytes
    with copy_mode():
        cp = decode_file(path)
    assert not isinstance(cp, np.memmap)


def test_pickle_codec_tail_for_object_arrays(tmpdir):
    arr = np.array([{"a": 1}, [2, 3]], dtype=object)
    codec = encoder_for(arr)
    assert isinstance(codec, PickleCodec)
    path = tmpdir / "o.npy"
    with open(path, "wb") as f:
        codec.write(f, arr)
    out = decode_file(path)        # chain falls back past RawCodec
    assert out[0] == {"a": 1} and out[1] == [2, 3]
    assert file_nbytes(path) == arr.nbytes


def test_custom_codec_registration(tmpdir):
    class NegCodec(Codec):
        """Stores the negated array (stand-in for a compressing codec)."""
        name = "neg"

        def accepts(self, arr):
            return arr.dtype == np.float32

        def write(self, f, arr):
            np.save(f, -arr)

        def read(self, path, prefer_view=True):
            return -np.load(path)

        def nbytes(self, path):
            return int(np.load(path, mmap_mode="r").nbytes)

    codec = register_codec(NegCodec())
    try:
        assert encoder_for(np.zeros(3, np.float32)) is codec
        assert isinstance(encoder_for(np.zeros(3, np.float64)), RawCodec)
        be = make_backend("file", root=tmpdir / "neg")
        a = np.arange(4, dtype=np.float32)
        be.put("k", a)
        np.testing.assert_array_equal(be.get("k"), a)   # roundtrips
    finally:
        unregister_codec(codec)


# -- backend view reads ---------------------------------------------------
def test_file_backend_views_survive_overwrite_and_delete(tmpdir):
    be = make_backend("file", root=tmpdir / "fb")
    a = np.arange(100.0)
    be.put("k", a)
    v = be.get("k")
    assert isinstance(v, np.memmap) and not v.flags.writeable
    be.put("k", a * 2)              # atomic replace under the live view
    np.testing.assert_array_equal(v, a)     # the inode is pinned
    np.testing.assert_array_equal(be.get("k"), a * 2)
    be.delete("k")
    np.testing.assert_array_equal(v, a)     # still pinned after unlink


def test_host_backend_read_is_aliasing_view():
    be = make_backend("host")
    a = np.arange(10.0)
    be.put("k", a)
    v = be.get("k")
    assert v.base is not None and not v.flags.writeable
    with copy_mode():
        c = be.get("k")
    assert c.base is None or c.base.base is None    # owned in copy mode
    np.testing.assert_array_equal(c, a)


def test_device_backend_read_is_readonly():
    be = make_backend("device")
    a = np.arange(10.0)
    be.put("k", a)
    v = be.get("k")
    assert not v.flags.writeable
    np.testing.assert_array_equal(v, a)


# -- provenance + mutation contract ---------------------------------------
def test_get_buf_and_partition_buf_carry_provenance(tmpdir):
    tm = make_tier_manager(root=str(tmpdir / "t"))
    try:
        du = DataUnit.from_array("du", np.arange(100.0), 4, tm.backends,
                                 tier="host", tier_manager=tm)
        b = tm.get_buf(du._key(0))
        assert b.source == "host" and not b.owned
        pb = du.partition_buf(1)
        assert pb.source == "host"
        assert not pb.view().flags.writeable
        w = du.partition_copy(1)
        w[:] = 0.0                  # sanctioned mutation: owned copy
        np.testing.assert_array_equal(du.partition(1),
                                      np.arange(100.0)[25:50])
    finally:
        tm.close()


def test_partition_is_readonly_and_views_survive_demotion(tmpdir):
    part_bytes = 25 * 8
    tm = make_tier_manager(host_budget=2 * part_bytes,
                           root=str(tmpdir / "t"), promote_threshold=0)
    try:
        du = DataUnit.from_array("du", np.arange(100.0), 4, tm.backends,
                                 tier="host", tier_manager=tm)
        v0 = du.partition(0)
        with pytest.raises(ValueError):
            v0[0] = -1.0
        expect = np.asarray(v0).copy()
        for i in range(4):          # budget 2: forces demotions to file
            du.partition(i)
        tm.drain(timeout=30)
        np.testing.assert_array_equal(np.asarray(v0), expect)
    finally:
        tm.close()


def test_replication_repair_never_mutates_reader_views(tmpdir):
    class _Pilot:
        def __init__(self, pid, tm):
            self.id, self.tier_manager = pid, tm

    tms = [make_tier_manager(root=str(tmpdir / f"p{i}"))
           for i in range(2)]
    pds = PilotDataService()
    try:
        for i, tm in enumerate(tms):
            pds.register_pilot(_Pilot(f"p{i}", tm))
        home = make_tier_manager(root=str(tmpdir / "home"))
        du = DataUnit.from_array("du", np.arange(64.0), 2, home.backends,
                                 tier="host", tier_manager=home)
        pds.register(du, replication=2)
        reader = du.partition(0)
        expect = np.asarray(reader).copy()
        assert pds.repair_once() > 0        # replicate onto both pilots
        np.testing.assert_array_equal(np.asarray(reader), expect)
        # a coherent overwrite invalidates replicas but not the live view
        du.update_partition(0, np.zeros(32))
        np.testing.assert_array_equal(np.asarray(reader), expect)
        np.testing.assert_array_equal(du.partition(0), np.zeros(32))
        home.close()
    finally:
        pds.close()
        for tm in tms:
            tm.close()


# -- counters / stats surface --------------------------------------------
def test_transport_counters_track_views_and_copies(tmpdir):
    be = make_backend("file", root=tmpdir / "c")
    a = np.arange(1000.0)
    be.put("k", a)
    TRANSPORT_STATS.reset()
    be.get("k")
    snap = TRANSPORT_STATS.snapshot()
    assert snap["bytes_viewed"] >= a.nbytes and snap["views"] >= 1
    assert snap["codec"].get("raw.decode") == 1
    with copy_mode():
        be.get("k")
    snap = TRANSPORT_STATS.snapshot()
    assert snap["bytes_copied"] >= a.nbytes and snap["copies"] >= 1


def test_session_stats_expose_transport():
    with PilotSession(name="transport-stats") as s:
        s.add_pilot(memory_gb=0.01)
        du = s.data("pts", np.arange(64.0), parts=2)
        du.partition(0)
        stats = s.stats()
    t = stats["transport"]
    assert {"bytes_viewed", "bytes_copied", "views", "copies",
            "codec"} <= set(t)


def test_read_partition_outside_pool_falls_back_home(tmpdir):
    tm = make_tier_manager(root=str(tmpdir / "t"))
    try:
        du = DataUnit.from_array("du", np.arange(16.0), 2, tm.backends,
                                 tier="host", tier_manager=tm)
        out = read_partition(du, 1)
        np.testing.assert_array_equal(out, np.arange(16.0)[8:])
        assert not out.flags.writeable
    finally:
        tm.close()


def test_read_partition_inside_pool_uses_pilot_tiers():
    with PilotSession(name="transport-worker") as s:
        s.add_pilot(memory_gb=0.01)
        du = s.data("pts", np.arange(64.0), parts=2)
        batch = s.submit_tasks(
            [lambda: float(np.sum(read_partition(du, 0)))])
        assert batch.results(timeout=30) == [float(np.sum(np.arange(32.0)))]
