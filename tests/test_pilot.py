"""Pilot-Abstraction behaviour: pilots, CUs, DUs, tiers, affinity scheduling,
late binding, retained-executable cache, MapReduce, KMeans."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ComputeDataManager, ComputeUnitDescription, DataUnit,
                        PilotComputeDescription, PilotComputeService, State,
                        kmeans, make_backend, make_blobs, map_reduce)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import FaultPolicy, SimulatedClusterBackend


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


@pytest.fixture
def backends(tmp_path):
    return {"file": make_backend("file", root=tmp_path / "file"),
            "object": make_backend("object", root=tmp_path / "obj"),
            "host": make_backend("host"),
            "device": make_backend("device")}


def test_pilot_lifecycle_and_cu(service):
    pilot = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    assert pilot.state == State.RUNNING
    manager = ComputeDataManager(service)
    cu = manager.run(lambda x: x * 2, 21)
    assert cu.result() == 42
    assert cu.state == State.DONE
    assert cu.pilot_id == pilot.id


def test_cu_failure_surfaces_exception(service):
    service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    cu = manager.run(lambda: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        cu.result()
    assert cu.state == State.FAILED


def test_late_binding_waits_for_pilot(service):
    """CU submitted before any pilot exists binds once one appears."""
    manager = ComputeDataManager(service)
    import threading
    out = {}

    def submit():
        out["cu"] = manager.run(lambda: "late")

    t = threading.Thread(target=submit)
    t.start()
    time.sleep(0.1)
    service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    t.join(5)
    assert out["cu"].result(10) == "late"


def test_data_unit_tier_staging(backends):
    arr = np.arange(4000, dtype=np.float32).reshape(500, 8)
    du = DataUnit.from_array("x", arr, 4, backends, tier="file")
    for tier in ("host", "device", "host", "file"):
        du.to_tier(tier)
        np.testing.assert_array_equal(
            np.concatenate(list(du.partitions())), arr)
    assert len(du.transfer_log) == 4
    assert all(t["bytes"] == arr.nbytes for t in du.transfer_log)


def test_affinity_scheduling_prefers_matching_pilot(service):
    p_a = service.submit_pilot(PilotComputeDescription(
        backend="inprocess", affinity="rack-a"))
    p_b = service.submit_pilot(PilotComputeDescription(
        backend="inprocess", affinity="rack-b"))
    manager = ComputeDataManager(service)
    desc = ComputeUnitDescription(fn=lambda: 0, affinity="rack-b")
    chosen = manager.select_pilot(desc)
    assert chosen.id == p_b.id


def test_device_residency_dominates_scheduling(service, backends):
    p_busy = service.submit_pilot(PilotComputeDescription(
        backend="inprocess", affinity="busy"))
    p_other = service.submit_pilot(PilotComputeDescription(
        backend="inprocess", affinity="other"))
    manager = ComputeDataManager(service)
    pts, _ = make_blobs(1000, 4, d=4)
    du = DataUnit.from_array("pts", pts, 2, backends, tier="device")
    desc = ComputeUnitDescription(fn=lambda: 0, input_data=(du,),
                                  affinity="other")
    s_busy = manager.score(p_busy, desc)
    desc_no_data = ComputeUnitDescription(fn=lambda: 0, affinity="other")
    assert s_busy > manager.score(p_busy, desc_no_data)


def test_retained_jit_cache_warm_start(service):
    pilot = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    calls = []

    def build():
        calls.append(1)
        import jax
        return jax.jit(lambda x: x + 1)

    f1 = pilot.jit_cached("inc", build)
    f2 = pilot.jit_cached("inc", build)
    assert f1 is f2 and len(calls) == 1


def test_map_reduce_tier_equivalence(backends, service):
    service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    pts = np.random.default_rng(0).normal(size=(1024, 4)).astype(np.float32)
    results = {}
    for tier in ("file", "host", "device"):
        du = DataUnit.from_array(f"mr-{tier}", pts, 4, backends, tier=tier)
        results[tier] = float(map_reduce(
            du, lambda p: jnp.sum(p.astype(jnp.float32)), lambda a, b: a + b,
            manager=manager))
    ref = float(pts.sum())
    for tier, val in results.items():
        assert abs(val - ref) < 1e-1 * abs(ref) + 1e-3, (tier, val, ref)


def test_kmeans_backend_equivalence_and_speedup_direction(backends, service):
    """The paper's Fig. 9 structure: same SSE across backends; memory tiers
    not slower than the (simulated-throttled) file tier."""
    from repro.core.memory import PROFILES, FileBackend
    pts, _ = make_blobs(20_000, 10, d=8, seed=1)
    slow_file = {"file": FileBackend(backends["file"].root / "slow",
                                     PROFILES["stampede_disk"]),
                 "host": backends["host"], "device": backends["device"]}
    du_file = DataUnit.from_array("kf", pts, 4, slow_file, tier="file")
    du_dev = DataUnit.from_array("kd", pts, 4, backends, tier="device")
    pilot = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    r_file = kmeans(du_file, k=8, iters=4, manager=manager)
    r_dev = kmeans(du_dev, k=8, iters=4, pilot=pilot)
    np.testing.assert_allclose(r_file.sse_history[-1], r_dev.sse_history[-1],
                               rtol=1e-3)
    # compare steady-state iterations (iter 0 is compile-dominated for both)
    assert (np.mean(r_dev.iter_seconds[1:]) < np.mean(r_file.iter_seconds[1:]))


def test_simulated_pilot_failure_and_manager_retry(service):
    register_backend(SimulatedClusterBackend(
        substrate="yarn",
        policy=FaultPolicy(fail_cu_ids=frozenset({"will-fail"}))))
    service.submit_pilot(PilotComputeDescription(backend="simulated"))
    manager = ComputeDataManager(service)
    desc = ComputeUnitDescription(fn=lambda: "ok", name="will-fail")
    assert manager.result_with_retry(desc, retries=2) == "ok"


def test_pilot_loss_recovery_via_retry(service):
    register_backend(SimulatedClusterBackend(
        substrate="slurm", policy=FaultPolicy(fail_devices_at=2)))
    dying = service.submit_pilot(PilotComputeDescription(backend="simulated"))
    manager = ComputeDataManager(service)
    for i in range(2):
        manager.run(lambda i=i: i).result()
    # pilot now dies; healthy inprocess pilot takes over via late binding
    service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    out = manager.result_with_retry(
        ComputeUnitDescription(fn=lambda: "survived"), retries=3)
    assert out == "survived"
