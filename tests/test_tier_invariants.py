"""Property-based tier invariants (hypothesis; deterministic stub fallback).

Random put/read/stage/delete/pressure/close sequences over a budgeted
three-tier hierarchy (device/host/checkpoint) must uphold the managed-
memory contract:

  * no tier ever exceeds its byte budget (peak accounting included);
  * no partition is ever lost: every live key is resident in exactly one
    managed tier and reads return exactly the bytes last written;
  * `close()` is a durability barrier: no half-written temporaries, no
    orphan checkpoint files (data files on disk correspond 1:1 with the
    fsync'd manifest), and a reopened store serves the same keys/bytes.
"""
import json
import shutil
import tempfile
from pathlib import Path

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import CapacityError, CheckpointBackend, TierManager, \
    make_backend

KB = 1024
_KEYS = [f"k{i}" for i in range(5)]
_TIERS = ("checkpoint", "host", "device")


def _decode(op: int):
    """One opcode -> (kind, key, tier, size_kb); modular decode keeps the
    hypothesis stub's integer streams expressive."""
    key = _KEYS[op % len(_KEYS)]
    kind = (op // 5) % 6        # 0,1: put  2: read  3: stage  4: delete
    #                             5: pressure-filler
    tier = _TIERS[(op // 30) % len(_TIERS)]
    size_kb = 1 + (op // 90) % 2
    return kind, key, tier, size_kb


def _apply(tm, model, op: int, fill_no: int) -> None:
    kind, key, tier, size_kb = _decode(op)
    if kind in (0, 1):
        val = np.full((size_kb * KB // 4,), op, dtype=np.float32)
        try:
            tm.put(key, val, tier)
            model[key] = val
        except CapacityError:
            pass                        # refusal is allowed; loss is not
    elif kind == 2 and key in model:
        np.testing.assert_array_equal(tm.get(key), model[key])
    elif kind == 3 and key in model:
        try:
            tm.stage(key, tier)
        except CapacityError:
            pass
    elif kind == 4:
        tm.delete(key)
        model.pop(key, None)
    elif kind == 5:
        try:
            tm.put(f"fill{fill_no % 3}",
                   np.full((KB // 4,), -1.0, np.float32), "device")
        except CapacityError:
            pass


def _check_invariants(tm, model, budgets) -> None:
    for tier, budget in budgets.items():
        if budget is not None:
            assert tm.usage(tier) <= budget, tier
            assert tm.peak_usage(tier) <= budget, tier
    for key, val in model.items():
        resident = [t for t in tm.order if key in tm.resident_keys(t)]
        assert len(resident) == 1, f"{key} resident in {resident}"
        np.testing.assert_array_equal(tm.get(key), val)


def _run_sequence(ops, budgets):
    root = Path(tempfile.mkdtemp(prefix="tier_inv_"))
    store = CheckpointBackend(root / "ckpt")
    tm = TierManager({"checkpoint": store,
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     budgets, promote_threshold=0)
    model = {}
    try:
        for n, op in enumerate(ops):
            _apply(tm, model, op, n)
            _check_invariants(tm, model, budgets)
        tm.close()
        _check_invariants(tm, model, budgets)   # close loses nothing
        return tm, store, model, root
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_random_ops_respect_budgets_and_never_lose_partitions(ops):
    _run_sequence(ops, {"device": 2 * KB, "host": 2 * KB})


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40),
       ckpt_budget_kb=st.sampled_from([4, 8, 0]))
def test_random_ops_with_bounded_checkpoint_tier(ops, ckpt_budget_kb):
    """Budgeting the durable floor too: refusals allowed, loss is not."""
    _run_sequence(ops, {"device": 2 * KB, "host": 2 * KB,
                        "checkpoint": ckpt_budget_kb * KB or None})


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_close_leaves_no_orphan_checkpoint_files(ops):
    """After close(): files on disk == fsync'd manifest == live
    checkpoint-resident keys, no temporaries, and a REOPENED store agrees
    byte-for-byte."""
    root = Path(tempfile.mkdtemp(prefix="tier_orphan_"))
    budgets = {"device": 2 * KB, "host": 2 * KB}
    store = CheckpointBackend(root / "ckpt")
    tm = TierManager({"checkpoint": store,
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     budgets, promote_threshold=0)
    model = {}
    try:
        for n, op in enumerate(ops):
            _apply(tm, model, op, n)
        tm.close()
        ckdir = root / "ckpt"
        on_disk = {p.relative_to(ckdir).with_suffix("").as_posix()
                   for p in ckdir.rglob("*.npy")}
        manifest = json.loads((ckdir / "MANIFEST.json").read_text())["keys"]
        resident = set(tm.resident_keys("checkpoint"))
        assert on_disk == set(manifest), "orphan or missing data files"
        assert resident <= on_disk, "resident key without a durable file"
        assert not list(ckdir.rglob("*.tmp")), "half-written temporary"
        reopened = CheckpointBackend(ckdir)
        assert set(reopened.keys()) == set(manifest)
        for key in resident:
            if key in model:
                np.testing.assert_array_equal(reopened.get(key), model[key])
            else:
                assert reopened.exists(key)     # pressure filler
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_reader_views_never_mutated_by_demotion_or_eviction(ops):
    """PR 8 mutation contract: every read hands out a write-protected
    view, and NO later plane activity — pressure demotions, evictions,
    stages, overwrites, deletes, close — may change the bytes under a
    reader's live view (moves are copy-first/delete-last; dropping a
    source only drops the store's reference, the view pins the backing
    bytes).  The cross-pilot repair path rides the same replicate ->
    copy-first protocol and is covered in tests/test_transport.py."""
    root = Path(tempfile.mkdtemp(prefix="tier_views_"))
    budgets = {"device": 2 * KB, "host": 2 * KB}
    store = CheckpointBackend(root / "ckpt")
    tm = TierManager({"checkpoint": store,
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     budgets, promote_threshold=0)
    model = {}
    held = []       # (live view, bytes it MUST keep showing)
    try:
        for n, op in enumerate(ops):
            kind, key, _tier, _size = _decode(op)
            if kind == 2 and key in model:
                v = tm.get(key)
                assert not v.flags.writeable, "plane read was writable"
                held.append((v, model[key].copy()))
            _apply(tm, model, op, n)
            for v, expect in held:
                np.testing.assert_array_equal(np.asarray(v), expect)
        tm.close()
        for v, expect in held:
            np.testing.assert_array_equal(np.asarray(v), expect)
            try:
                v[...] = 0.0
                raise AssertionError("held view accepted a write")
            except ValueError:
                pass
    finally:
        shutil.rmtree(root, ignore_errors=True)


# -- dispatch-queue properties (the task engine's backpressure bound) -----
from repro.core.taskengine import DispatchQueue

_Q_BOUND = 8


def _q_decode(op: int):
    """One opcode -> (kind, size); modular decode, like _decode above.
    kind 0: put  1: put_force  2: take  3: close (rare: op%23==0)."""
    if op % 23 == 0:
        return 3, 0
    return op % 3, 1 + (op // 7) % 6


def _q_apply(q, model, op: int) -> None:
    """Apply one decoded op to the queue and the reference model.
    `model` is {"pending": [...], "taken": [...], "forced": int}."""
    kind, size = _q_decode(op)
    if kind == 0:
        items = [f"i{q.accepted + j}" for j in range(size)]
        n = q.put(items, timeout=0)         # never block: partial accept
        model["pending"].extend(items[:n])
    elif kind == 1:
        items = [f"f{q.accepted + j}" for j in range(size)]
        n = q.put_force(items)
        assert n in (0, size)               # all-or-nothing (closed = 0)
        model["pending"].extend(items[:n])
        model["forced"] += n
    elif kind == 2:
        chunk = q.take(timeout=0)
        if chunk:
            # FIFO: the chunk is exactly the next pending prefix
            assert chunk == model["pending"][:len(chunk)]
            del model["pending"][:len(chunk)]
            model["taken"].extend(chunk)
    else:
        q.close()


def _q_invariants(q, model) -> None:
    # conservation: accounting matches the model at every step
    assert q.depth == q.accepted - q.taken
    assert q.depth == len(model["pending"])
    assert q.taken == len(model["taken"])
    # the bound is violated only by what put_force explicitly forced
    assert q.depth <= _Q_BOUND + model["forced"]


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=60))
def test_dispatch_queue_random_ops_conserve_accounting(ops):
    """Arbitrary put/put_force/take/close interleavings: accounting is
    conserved at every step, the backlog drains exactly once in FIFO
    order after close — no task dropped, none double-taken."""
    q = DispatchQueue(bound=_Q_BOUND, chunk=3)
    model = {"pending": [], "taken": [], "forced": 0}
    for op in ops:
        _q_apply(q, model, op)
        _q_invariants(q, model)
    q.close()
    while True:                             # drain protocol
        chunk = q.take(timeout=0)
        if not chunk:
            assert chunk is None            # closed AND empty -> None
            break
        assert chunk == model["pending"][:len(chunk)]
        del model["pending"][:len(chunk)]
        model["taken"].extend(chunk)
    assert not model["pending"]
    assert q.taken == q.accepted            # every accepted item ran
    assert q.depth == 0
    # total order: taken == accepted stream, exactly once each
    assert len(model["taken"]) == len(set(model["taken"])) == q.accepted


def test_dispatch_queue_threaded_interleaving_no_loss_no_dup():
    """Producers (bounded + forced) race consumers: after close+drain
    every accepted item was taken exactly once."""
    import threading as _t

    q = DispatchQueue(bound=16, chunk=4)
    taken = []
    tlock = _t.Lock()
    done = _t.Event()

    def consumer():
        while True:
            chunk = q.take(timeout=0.5)
            if chunk is None:
                return
            if chunk:
                with tlock:
                    taken.extend(chunk)

    def producer(tag, force):
        for j in range(200):
            item = f"{tag}-{j}"
            if force:
                q.put_force([item])
            else:
                while not q.put([item], timeout=0.1) and not done.is_set():
                    pass

    consumers = [_t.Thread(target=consumer) for _ in range(3)]
    producers = [_t.Thread(target=producer, args=(f"p{k}", k == 2))
                 for k in range(3)]
    for t in consumers + producers:
        t.start()
    for t in producers:
        t.join(30)
        assert not t.is_alive()
    done.set()
    q.close()
    for t in consumers:
        t.join(30)
        assert not t.is_alive()
    assert q.taken == q.accepted == 600
    assert q.depth == 0
    assert len(taken) == len(set(taken)) == 600
