"""Property-based tier invariants (hypothesis; deterministic stub fallback).

Random put/read/stage/delete/pressure/close sequences over a budgeted
three-tier hierarchy (device/host/checkpoint) must uphold the managed-
memory contract:

  * no tier ever exceeds its byte budget (peak accounting included);
  * no partition is ever lost: every live key is resident in exactly one
    managed tier and reads return exactly the bytes last written;
  * `close()` is a durability barrier: no half-written temporaries, no
    orphan checkpoint files (data files on disk correspond 1:1 with the
    fsync'd manifest), and a reopened store serves the same keys/bytes.
"""
import json
import shutil
import tempfile
from pathlib import Path

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import CapacityError, CheckpointBackend, TierManager, \
    make_backend

KB = 1024
_KEYS = [f"k{i}" for i in range(5)]
_TIERS = ("checkpoint", "host", "device")


def _decode(op: int):
    """One opcode -> (kind, key, tier, size_kb); modular decode keeps the
    hypothesis stub's integer streams expressive."""
    key = _KEYS[op % len(_KEYS)]
    kind = (op // 5) % 6        # 0,1: put  2: read  3: stage  4: delete
    #                             5: pressure-filler
    tier = _TIERS[(op // 30) % len(_TIERS)]
    size_kb = 1 + (op // 90) % 2
    return kind, key, tier, size_kb


def _apply(tm, model, op: int, fill_no: int) -> None:
    kind, key, tier, size_kb = _decode(op)
    if kind in (0, 1):
        val = np.full((size_kb * KB // 4,), op, dtype=np.float32)
        try:
            tm.put(key, val, tier)
            model[key] = val
        except CapacityError:
            pass                        # refusal is allowed; loss is not
    elif kind == 2 and key in model:
        np.testing.assert_array_equal(tm.get(key), model[key])
    elif kind == 3 and key in model:
        try:
            tm.stage(key, tier)
        except CapacityError:
            pass
    elif kind == 4:
        tm.delete(key)
        model.pop(key, None)
    elif kind == 5:
        try:
            tm.put(f"fill{fill_no % 3}",
                   np.full((KB // 4,), -1.0, np.float32), "device")
        except CapacityError:
            pass


def _check_invariants(tm, model, budgets) -> None:
    for tier, budget in budgets.items():
        if budget is not None:
            assert tm.usage(tier) <= budget, tier
            assert tm.peak_usage(tier) <= budget, tier
    for key, val in model.items():
        resident = [t for t in tm.order if key in tm.resident_keys(t)]
        assert len(resident) == 1, f"{key} resident in {resident}"
        np.testing.assert_array_equal(tm.get(key), val)


def _run_sequence(ops, budgets):
    root = Path(tempfile.mkdtemp(prefix="tier_inv_"))
    store = CheckpointBackend(root / "ckpt")
    tm = TierManager({"checkpoint": store,
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     budgets, promote_threshold=0)
    model = {}
    try:
        for n, op in enumerate(ops):
            _apply(tm, model, op, n)
            _check_invariants(tm, model, budgets)
        tm.close()
        _check_invariants(tm, model, budgets)   # close loses nothing
        return tm, store, model, root
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_random_ops_respect_budgets_and_never_lose_partitions(ops):
    _run_sequence(ops, {"device": 2 * KB, "host": 2 * KB})


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40),
       ckpt_budget_kb=st.sampled_from([4, 8, 0]))
def test_random_ops_with_bounded_checkpoint_tier(ops, ckpt_budget_kb):
    """Budgeting the durable floor too: refusals allowed, loss is not."""
    _run_sequence(ops, {"device": 2 * KB, "host": 2 * KB,
                        "checkpoint": ckpt_budget_kb * KB or None})


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_close_leaves_no_orphan_checkpoint_files(ops):
    """After close(): files on disk == fsync'd manifest == live
    checkpoint-resident keys, no temporaries, and a REOPENED store agrees
    byte-for-byte."""
    root = Path(tempfile.mkdtemp(prefix="tier_orphan_"))
    budgets = {"device": 2 * KB, "host": 2 * KB}
    store = CheckpointBackend(root / "ckpt")
    tm = TierManager({"checkpoint": store,
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     budgets, promote_threshold=0)
    model = {}
    try:
        for n, op in enumerate(ops):
            _apply(tm, model, op, n)
        tm.close()
        ckdir = root / "ckpt"
        on_disk = {p.relative_to(ckdir).with_suffix("").as_posix()
                   for p in ckdir.rglob("*.npy")}
        manifest = json.loads((ckdir / "MANIFEST.json").read_text())["keys"]
        resident = set(tm.resident_keys("checkpoint"))
        assert on_disk == set(manifest), "orphan or missing data files"
        assert resident <= on_disk, "resident key without a durable file"
        assert not list(ckdir.rglob("*.tmp")), "half-written temporary"
        reopened = CheckpointBackend(ckdir)
        assert set(reopened.keys()) == set(manifest)
        for key in resident:
            if key in model:
                np.testing.assert_array_equal(reopened.get(key), model[key])
            else:
                assert reopened.exists(key)     # pressure filler
    finally:
        shutil.rmtree(root, ignore_errors=True)
