"""MoE dispatch invariants: sort-based positions, capacity, grouping,
router numerics (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.moe import _positions_in_expert, _route, moe_ffn, router_load
from repro.models.common import init_params
from repro.models import moe as moe_mod


@settings(max_examples=20, deadline=None)
@given(g=st.integers(1, 4), t=st.integers(1, 128), e=st.integers(1, 16),
       seed=st.integers(0, 100))
def test_positions_in_expert_is_occurrence_rank(g, t, e, seed):
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.integers(0, e, size=(g, t)), jnp.int32)
    pos = np.asarray(_positions_in_expert(flat))
    for gi in range(g):
        seen = {}
        for ti in range(t):
            eid = int(flat[gi, ti])
            assert pos[gi, ti] == seen.get(eid, 0)
            seen[eid] = seen.get(eid, 0) + 1


def _moe_cfg(cf=8.0, experts=4, top_k=2):
    cfg = reduced(get_config("mixtral_8x22b"))
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=cf, num_experts=experts, top_k=top_k))


def test_moe_capacity_drops_tokens():
    """cf -> 0 forces drops; output rows for dropped tokens shrink toward
    the shared-expert-only value (here: zero)."""
    cfg_hi = _moe_cfg(cf=8.0)
    cfg_lo = dataclasses.replace(cfg_hi, moe=dataclasses.replace(
        cfg_hi.moe, capacity_factor=0.05))
    params = init_params(jax.random.key(0), moe_mod.moe_specs(cfg_hi))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg_hi.d_model),
                          jnp.bfloat16)
    y_hi, _ = moe_ffn(params, x, cfg_hi)
    y_lo, _ = moe_ffn(params, x, cfg_lo)
    n_hi = float(jnp.linalg.norm(y_hi.astype(jnp.float32)))
    n_lo = float(jnp.linalg.norm(y_lo.astype(jnp.float32)))
    assert n_lo < n_hi


def test_moe_grouping_matches_ungrouped():
    """Decode regrouping (s*k < E) must not change results when capacity is
    ample — same tokens, same experts, different group partitioning."""
    cfg = _moe_cfg(cf=32.0, experts=16, top_k=2)
    params = init_params(jax.random.key(0), moe_mod.moe_specs(cfg))
    xb = jax.random.normal(jax.random.key(1), (8, 1, cfg.d_model),
                           jnp.bfloat16)
    y_dec, _ = moe_ffn(params, xb, cfg)          # s*k=2 < 16 -> regroups
    y_ref, _ = moe_ffn(params, xb.reshape(1, 8, cfg.d_model), cfg)
    np.testing.assert_allclose(np.asarray(y_dec.reshape(1, 8, -1), np.float32),
                               np.asarray(y_ref, np.float32), atol=1e-2,
                               rtol=1e-2)


def test_router_weights_normalized():
    cfg = _moe_cfg()
    params = init_params(jax.random.key(0), moe_mod.moe_specs(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.bfloat16)
    w, idx, aux = _route(params, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < cfg.moe.num_experts
    assert float(aux) >= 0.0


def test_aux_free_router_bias_shifts_selection():
    """DeepSeek aux-free balancing: raising one expert's bias attracts load."""
    cfg = reduced(get_config("deepseek_v3_671b"))
    params = init_params(jax.random.key(0), moe_mod.moe_specs(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model), jnp.bfloat16)
    load0 = np.asarray(router_load(params, x, cfg))
    params2 = dict(params)
    params2["router_bias"] = params["router_bias"] + jnp.zeros_like(
        params["router_bias"]).at[0].set(10.0)
    load1 = np.asarray(router_load(params2, x, cfg))
    assert load1[0] > load0[0]
    # bias affects selection only, not weights of chosen experts' outputs
    w, idx, _ = _route(params2, x, cfg.moe)
    assert float(w.min()) >= 0.0
