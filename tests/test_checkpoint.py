"""Checkpoint save/restore: bf16 round-trip, async overlap, GC, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.train.steps import TrainState
from repro.optim.adamw import OptState


def _state(key=0):
    k = jax.random.key(key)
    params = {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
              "scan": jax.random.normal(k, (4, 8, 8), jnp.bfloat16)}
    opt = OptState(m=jax.tree.map(lambda p: p.astype(jnp.float32), params),
                   v=jax.tree.map(lambda p: p.astype(jnp.float32), params),
                   count=jnp.int32(7))
    return TrainState(params, opt)


def test_roundtrip_bf16(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    state = _state()
    ckpt.save(10, state)
    restored, step = ckpt.restore(state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    state = _state()
    ckpt.save(5, state, blocking=False)
    restored, step = ckpt.restore(state)  # restore waits for the writer
    assert step == 5
    assert int(restored.opt_state.count) == 7


def test_gc_keeps_latest(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        ckpt.save(s, state)
    assert sorted(ckpt.list_steps()) == [3, 4]


def test_restore_specific_step(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=5)
    s1, s2 = _state(1), _state(2)
    ckpt.save(1, s1)
    ckpt.save(2, s2)
    r1, _ = ckpt.restore(s1, step=1)
    np.testing.assert_array_equal(np.asarray(r1.params["w"]),
                                  np.asarray(s1.params["w"]))


def test_elastic_restore_with_new_sharding(tmp_path):
    """Restore with explicit shardings = the re-mesh path."""
    ckpt = CheckpointManager(tmp_path)
    state = _state()
    ckpt.save(1, state)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        state)
    restored, _ = ckpt.restore(state, shardings=sh)
    assert isinstance(restored.params["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                  np.asarray(state.params["w"]))
