"""HLO cost model: trip-count multiplication, dot flops, collective bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import HloCostModel, shape_bytes
from repro.roofline.analysis import (CollectiveStats, Roofline,
                                     model_flops_estimate)


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplied():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    cs = HloCostModel(_compile(scanned, x, ws)).cost()
    cu = HloCostModel(_compile(unrolled, x, ws)).cost()
    analytic = 2 * 128 * 256 * 256 * 8
    assert cs.flops == pytest.approx(analytic, rel=0.01)
    assert cu.flops == pytest.approx(analytic, rel=0.01)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = HloCostModel(_compile(f, a, b)).cost()
    assert c.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_shape_bytes_parses_layouts_and_tuples():
    assert shape_bytes("f32[16,8]{1,0}") == 512
    assert shape_bytes("(bf16[4,4], s32[2])") == 32 + 8
    assert shape_bytes("pred[10]") == 10


def test_collective_bytes_ring_model():
    hlo = """
HloModule test
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %all-reduce = f32[64]{0} all-reduce(%p), channel_id=1, replica_groups=[2,8]<=[16], to_apply=%add
}
"""
    c = HloCostModel(hlo).cost()
    # ring all-reduce over 8: 2*(7/8)*256 bytes
    assert c.coll_bytes == pytest.approx(2 * (7 / 8) * 256)
    assert c.coll_by_kind["all-reduce"] == c.coll_bytes


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 flops_per_device=197e12, bytes_per_device=819e9 * 2,
                 coll_bytes_per_device=50e9 * 0.5, coll_by_kind={},
                 peak_mem_bytes=1, arg_bytes=1, model_flops=1.0,
                 hlo_flops_global=2.0)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.roofline_fraction == pytest.approx(0.5)


def test_model_flops_estimate_moe_uses_active():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("deepseek_v3_671b")
    dense_equiv = 6.0 * cfg.num_params() * 256 * 4096
    active = model_flops_estimate(cfg, SHAPES["train_4k"])
    assert active < 0.2 * dense_equiv  # top-8/256 + shared << dense
