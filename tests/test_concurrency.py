"""Thread-hammer tests: backends, DataUnit.to_tier, and TierManager staging.

Invariant under test: readers racing with staging observe either-tier-
consistent data — the value from the old tier or the new one — and never a
KeyError/FileNotFoundError hole (moves copy first, delete last)."""
import threading

import numpy as np
import pytest

from repro.core import DataUnit, TierManager, make_backend
from repro.core.memory import DeviceBackend, HostMemoryBackend


def _hammer(workers, seconds=1.0):
    """Run worker callables in threads until the deadline; re-raise the
    first error any of them hit."""
    stop = threading.Event()
    errors = []

    def wrap(fn):
        try:
            while not stop.is_set():
                fn()
        except Exception as e:  # noqa: BLE001
            errors.append(e)
            stop.set()

    threads = [threading.Thread(target=wrap, args=(w,)) for w in workers]
    for t in threads:
        t.start()
    stop.wait(seconds)
    stop.set()
    for t in threads:
        t.join(20)
    if errors:
        raise errors[0]


@pytest.mark.parametrize("backend_cls", [HostMemoryBackend, DeviceBackend])
def test_backend_put_get_delete_hammer(backend_cls):
    be = backend_cls()
    vals = {f"k{i}": np.full((64,), i, np.float32) for i in range(8)}
    for k, v in vals.items():
        be.put(k, v)

    def reader():
        for k, v in vals.items():
            got = np.asarray(be.get(k))
            assert got[0] == v[0]

    def writer():
        for k, v in vals.items():
            be.put(k, v)

    def churner():
        be.put("tmp", np.zeros(8, np.float32))
        be.delete("tmp")

    _hammer([reader, reader, writer, churner], seconds=1.0)


def test_dataunit_to_tier_reads_never_hole(tmp_path):
    """Unmanaged DU: one mover cycles tiers while readers scan partitions."""
    backends = {"file": make_backend("file", root=tmp_path),
                "host": make_backend("host"),
                "device": make_backend("device")}
    arr = np.arange(1024, dtype=np.float32).reshape(128, 8)
    du = DataUnit.from_array("c", arr, 4, backends, tier="host")
    cycle = ["device", "host", "file", "host"]
    state = {"i": 0}

    def mover():
        du.to_tier(cycle[state["i"] % len(cycle)])
        state["i"] += 1

    def reader():
        total = sum(float(np.asarray(p).sum()) for p in du.partitions())
        assert total == float(arr.sum())

    _hammer([mover, reader, reader, reader], seconds=1.5)


def test_tier_manager_staging_hammer(tmp_path):
    """Managed DU: two movers + async prefetches race four readers."""
    tm = TierManager({"file": make_backend("file", root=tmp_path),
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     promote_threshold=0)
    arr = np.arange(2048, dtype=np.float32).reshape(256, 8)
    du = DataUnit.from_array("m", arr, 8, tm.backends, tier="host",
                             tier_manager=tm)
    tiers = ["device", "host", "file"]
    idx = {"a": 0, "b": 0}

    def mover(tag, offset):
        def go():
            i = idx[tag]
            tm.stage(du._key(i % du.num_partitions),
                     tiers[(i + offset) % len(tiers)])
            idx[tag] = i + 1
        return go

    def async_mover():
        for i in range(du.num_partitions):
            tm.stage_async(du._key(i), tiers[i % len(tiers)])

    def reader():
        total = sum(float(np.asarray(p).sum()) for p in du.partitions())
        assert total == float(arr.sum())

    _hammer([mover("a", 0), mover("b", 1), async_mover,
             reader, reader, reader, reader], seconds=1.5)
    tm.drain(timeout=30)
    # every partition still accounted for in exactly one tier
    res = du.residency()
    assert sum(res.values()) == du.num_partitions
    np.testing.assert_array_equal(
        np.concatenate(list(du.partitions())), arr)


def test_budgeted_staging_hammer_respects_budget(tmp_path):
    """Concurrent promotions into a bounded device tier never overshoot."""
    part_kb = 4
    tm = TierManager({"host": make_backend("host"),
                      "device": make_backend("device")},
                     {"device": 3 * part_kb * 1024},
                     promote_threshold=2)
    arr = np.arange(part_kb * 256 * 8, dtype=np.float32)
    du = DataUnit.from_array("b", arr, 8, tm.backends, tier="host",
                             tier_manager=tm)

    def reader():
        for i in range(du.num_partitions):
            du.partition(i)

    def promoter():
        for i in range(du.num_partitions):
            tm.stage_async(du._key(i), "device")

    _hammer([reader, reader, promoter], seconds=1.5)
    tm.drain(timeout=30)
    assert tm.peak_usage("device") <= 3 * part_kb * 1024
    np.testing.assert_array_equal(
        np.concatenate(list(du.partitions())), arr)
    tm.close()


def test_demotion_copy_runs_off_the_metadata_lock(tmp_path):
    """Pressure demotion uses the same copy-first/delete-last protocol as
    `stage`: while a victim's bytes drain into a (gated) cold tier, the
    metadata lock is free — concurrent stages of OTHER keys complete and
    the manager stays introspectable — and the move lands atomically."""
    from repro.core.memory import FileBackend

    gate = threading.Event()
    copy_started = threading.Event()

    class GatedFile(FileBackend):
        def put(self, name, value):
            if name == "victim":
                copy_started.set()
                assert gate.wait(20)
            super().put(name, value)

    kb = 1024
    tm = TierManager({"file": GatedFile(tmp_path),
                      "host": make_backend("host")},
                     {"host": 2 * kb}, promote_threshold=0)
    tm.put("victim", np.zeros(kb // 4, np.float32), "host")
    tm.put("other", np.ones(kb // 4, np.float32), "host")
    tm.get("other")                       # victim is now the LRU entry

    t = threading.Thread(                 # displaces victim -> gated demote
        target=tm.put,
        args=("new", np.full(kb // 4, 2.0, np.float32), "host"))
    t.start()
    assert copy_started.wait(10)
    # the demote copy is in flight and blocked on the gate; metadata-lock
    # holders must still make progress
    assert tm.stage("other", "file") == "file"
    assert tm.tier_of("victim") == "host"     # flip happens copy-first
    np.testing.assert_array_equal(tm.get("victim"),
                                  np.zeros(kb // 4, np.float32))
    gate.set()
    t.join(20)
    assert not t.is_alive()
    assert tm.tier_of("victim") == "file"
    assert tm.tier_of("new") == "host"
    assert tm.usage("host") <= 2 * kb
    np.testing.assert_array_equal(tm.get("victim"),
                                  np.zeros(kb // 4, np.float32))
    tm.close()


def test_checkpoint_demotion_copy_runs_off_the_metadata_lock(tmp_path):
    """Checkpoint-tier variant of the demote-off-lock test: while a
    victim's bytes drain into a gated PERSISTENT store, concurrent
    readers of the victim and stagers of other keys make progress, and
    the spill lands atomically (copy-first/delete-last)."""
    from repro.core.memory import CheckpointBackend

    gate = threading.Event()
    copy_started = threading.Event()

    class GatedCheckpoint(CheckpointBackend):
        def put(self, name, value):
            if name == "victim":
                copy_started.set()
                assert gate.wait(20)
            super().put(name, value)

    kb = 1024
    tm = TierManager({"checkpoint": GatedCheckpoint(tmp_path / "ck"),
                      "host": make_backend("host")},
                     {"host": 2 * kb}, promote_threshold=0)
    tm.put("victim", np.zeros(kb // 4, np.float32), "host")
    tm.put("other", np.ones(kb // 4, np.float32), "host")
    tm.get("other")                       # victim is now the LRU entry

    t = threading.Thread(                 # displaces victim -> gated spill
        target=tm.put,
        args=("new", np.full(kb // 4, 2.0, np.float32), "host"))
    t.start()
    assert copy_started.wait(10)
    # the spill is in flight and blocked on the gate; metadata-lock
    # holders must still make progress, and the victim must stay readable
    assert tm.stage("other", "checkpoint") == "checkpoint"
    assert tm.tier_of("victim") == "host"     # flip happens copy-first
    np.testing.assert_array_equal(tm.get("victim"),
                                  np.zeros(kb // 4, np.float32))
    gate.set()
    t.join(20)
    assert not t.is_alive()
    assert tm.tier_of("victim") == "checkpoint"
    assert tm.tier_of("new") == "host"
    assert tm.usage("host") <= 2 * kb
    np.testing.assert_array_equal(tm.get("victim"),
                                  np.zeros(kb // 4, np.float32))
    tm.close()


def test_checkpoint_spill_hammer_readers_never_observe_holes(tmp_path):
    """Concurrent readers during host->checkpoint demotions (and async
    promotions back) never observe a missing partition, budgets hold, and
    the post-close store is consistent with the final residency."""
    part = 1024
    tm = TierManager({"checkpoint": make_backend(
                          "checkpoint", root=tmp_path / "ck"),
                      "host": make_backend("host"),
                      "device": make_backend("device")},
                     {"device": 2 * part, "host": 2 * part},
                     promote_threshold=0)
    arr = np.arange(part * 2, dtype=np.float32).reshape(8, part // 4)
    du = DataUnit.from_array("ck", arr, 8, tm.backends, tier="device",
                             tier_manager=tm)
    assert du.resident_fraction("checkpoint") > 0   # pressure spilled

    idx = {"n": 0}

    def churner():
        # displacement pressure keeps demotions (and re-promotions) flowing
        i = idx["n"]
        tm.stage_async(du._key(i % 8), ("device", "host")[i % 2])
        idx["n"] = i + 1

    def reader():
        total = sum(float(np.asarray(p).sum()) for p in du.partitions())
        assert total == float(arr.sum())

    _hammer([churner, reader, reader, reader], seconds=1.5)
    tm.drain(timeout=30)
    assert tm.peak_usage("device") <= 2 * part
    assert tm.peak_usage("host") <= 2 * part
    res = du.residency()
    assert sum(res.values()) == du.num_partitions
    np.testing.assert_array_equal(
        np.concatenate(list(du.partitions())), arr)
    tm.close()
    # every checkpoint-resident partition is durably on disk post-close
    store = tm.backends["checkpoint"]
    for k in tm.resident_keys("checkpoint"):
        assert (tmp_path / "ck" / f"{k}.npy").exists()
        assert store.exists(k)


def test_stager_close_drains_inflight_deterministically(tmp_path):
    """close() with moves in flight: queued stages are cancelled, running
    ones land atomically, stager threads are joined (no leaks between
    tests), and the manager stays readable and consistent afterwards."""
    from repro.core.memory import FileBackend, TierProfile

    before = set(threading.enumerate())
    slow = TierProfile("slow", read_bw=2e6, write_bw=2e6, latency=5e-3,
                       simulate=True)
    tm = TierManager({"file": FileBackend(tmp_path, slow),
                      "host": make_backend("host")},
                     promote_threshold=0, max_workers=2)
    arr = np.arange(4096, dtype=np.float32)
    du = DataUnit.from_array("s", arr, 16, tm.backends, tier="file",
                             tier_manager=tm)
    futs = [tm.stage_async(du._key(i), "host") for i in range(16)]
    tm.close()
    # deterministic: every future resolved or cancelled, none still running
    assert all(f.done() for f in futs)
    leaked = [t for t in set(threading.enumerate()) - before
              if "tier-stager" in t.name and t.is_alive()]
    assert not leaked
    # idempotent, and post-close stage requests resolve immediately
    tm.close()
    assert tm.stage_async(du._key(0), "host").done()
    # drain tolerates the cancelled futures
    tm.drain(timeout=5)
    # no half-applied move: every partition in exactly one tier, data intact
    res = du.residency()
    assert sum(res.values()) == du.num_partitions
    np.testing.assert_array_equal(
        np.concatenate(list(du.partitions())), arr)


def test_task_engine_stress_producers_vs_lose_volatile(tmp_path):
    """Scheduling-plane stress: many producer threads batch-submitting
    against 4 pilots (sharded stats locks, per-pilot dispatch queues)
    while volatile-memory loss fires mid-flight.  Every future must
    resolve — a value directly, or through the engine's re-bind retry —
    and nothing may deadlock: data reads fall back through the
    PilotDataService to the home placement when a pilot's tiers refuse
    (lose_volatile raises CapacityError on new placements), and failed
    tasks re-bind onto surviving-tier pilots."""
    import random

    from repro.core import PilotSession
    from repro.core.taskengine import current_pilot

    arr = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
    part_sums = [float(arr[i].sum()) for i in range(8)]
    with PilotSession(name="engine-stress") as s:
        pilots = s.add_pilots(4, memory_gb=0.001, task_workers=2,
                              dispatch_queue_depth=64)
        du = s.data("stress", arr, parts=8)
        stop = threading.Event()

        def chaos():
            rng = random.Random(1234)
            while not stop.is_set():
                p = rng.choice(pilots)
                if p.tier_manager is not None:
                    p.tier_manager.lose_volatile()
                stop.wait(0.02)

        def read_task(i):
            # read through the executing pilot's own replica layer; a
            # lost tier refuses placement and the read falls back home
            p = current_pilot()
            return float(np.asarray(du.partition(i, pilot=p)).sum())

        def make_flaky():
            state = {"n": 0}
            lk = threading.Lock()

            def flaky():
                with lk:
                    state["n"] += 1
                    if state["n"] == 1:
                        raise RuntimeError("transient")
                return -1.0
            return flaky

        errors = []

        def producer(seed):
            try:
                rng = random.Random(seed)
                for _ in range(4):
                    items = []
                    want = []
                    for _ in range(60):
                        if rng.random() < 0.2:
                            items.append(make_flaky())
                            want.append(-1.0)
                        else:
                            i = rng.randrange(8)
                            items.append((read_task, (i,)))
                            want.append(part_sums[i])
                    batch = s.submit_tasks(items, retries=3)
                    got = batch.results(timeout=60)
                    assert got == want
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        ct = threading.Thread(target=chaos, daemon=True)
        ct.start()
        producers = [threading.Thread(target=producer, args=(s_,))
                     for s_ in range(6)]
        for t in producers:
            t.start()
        for t in producers:
            t.join(120)
            assert not t.is_alive(), "producer deadlocked"
        stop.set()
        ct.join(10)
        if errors:
            raise errors[0]
        st = s.manager.stats()
        assert st["submitted"] >= 6 * 4 * 60   # re-binds only add to it
