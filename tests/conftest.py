import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real (single) device; only launch/dryrun.py and
# explicit subprocess tests use placeholder device grids.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    TESTS = Path(__file__).resolve().parent
    if str(TESTS) not in sys.path:
        sys.path.insert(0, str(TESTS))
    import _hypothesis_stub  # noqa: E402

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
