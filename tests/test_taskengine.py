"""Task-engine contract: batched-vs-single parity, pool lifecycle,
backpressure, batch scoring == N single scores, retry/re-bind.

The engine (repro.core.taskengine) is the raptor-style batched dispatch
plane: resident per-pilot worker pools fed through backpressure-bounded
queues, the whole batch scored in one SchedulingPolicy pass.  These tests
pin the contracts the throughput work must never trade away: results
match the per-CU path exactly, no accepted task is lost to shutdown, the
bound is a real bound, batch scoring is bit-for-bit N single scores, and
failures re-bind with the PR 4 exclusion semantics.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (ComputeDataManager, ComputeUnitDescription,
                        DataUnit, LocalityPolicy, PilotComputeDescription,
                        PilotComputeService, PilotSession, make_backend)
from repro.core.taskengine import (DispatchQueue, TaskError, WorkerPool,
                                   current_pilot)


# -- batched vs single parity ---------------------------------------------
def test_batched_results_match_single_submission():
    with PilotSession() as s:
        s.add_pilot()
        want = [s.run(pow, 2, i).result(timeout=30) for i in range(20)]
        got = s.submit_tasks([(pow, (2, i)) for i in range(20)])
        assert got.results(timeout=30) == want


def test_submit_tasks_accepts_all_item_forms():
    with PilotSession() as s:
        s.add_pilot()
        batch = s.submit_tasks([
            lambda: "bare",
            (int, ("ff", 16)),
            (dict, (), {"a": 1}),
            ComputeUnitDescription(fn=lambda x: x + 1, args=(41,),
                                   name="desc-task"),
        ])
        assert batch.results(timeout=30) == ["bare", 255, {"a": 1}, 42]
        with pytest.raises(TypeError):
            s.submit_tasks([42])


def test_task_error_surfaces_and_batch_keeps_order():
    def boom():
        raise ValueError("boom")

    with PilotSession() as s:
        s.add_pilot()
        batch = s.submit_tasks([lambda: 1, boom, lambda: 3])
        assert batch.wait(timeout=30)
        assert batch[0].result() == 1
        assert batch[2].result() == 3
        with pytest.raises(ValueError, match="boom"):
            batch[1].result()
        assert isinstance(batch[1].exception(), ValueError)
        with pytest.raises(ValueError):
            batch.results()


def test_tasks_run_pinned_to_their_pilot():
    """current_pilot() inside a task is the bound pilot — the raptor
    property that lets function tasks read the pilot's tiers without
    re-staging."""
    with PilotSession() as s:
        p = s.add_pilot()
        batch = s.submit_tasks([lambda: current_pilot().id] * 8)
        assert batch.results(timeout=30) == [p.id] * 8
    assert current_pilot() is None      # only worker threads are pinned


# -- worker-pool lifecycle ------------------------------------------------
def test_pool_drains_on_close_no_task_lost():
    """close() is a drain barrier: every accepted task runs, the worker
    threads join, and nothing leaks."""
    before = {t.name for t in threading.enumerate()}
    done = []
    with PilotSession() as s:
        s.add_pilot(task_workers=2)
        batch = s.submit_tasks([lambda i=i: done.append(i) or i
                                for i in range(500)])
        # close() without waiting: the drain must finish the backlog
    assert batch.done
    assert sorted(t.result() for t in batch) == list(range(500))
    assert len(done) == 500
    leaked = [t for t in threading.enumerate()
              if "-taskw" in t.name and t.name not in before and t.is_alive()]
    assert not leaked


def test_pool_rejects_after_close_and_never_started_pool_drains_inline():
    svc = PilotComputeService()
    pilot = svc.submit_pilot(PilotComputeDescription())
    pool = pilot.worker_pool
    try:
        # enqueue without starting workers, then close: the backlog is
        # finalized inline (accounting conserved), not stranded
        q = pool.queue
        assert q.put([1, 2, 3]) == 3     # raw items: never executed, but
        q.close()                        # the queue contract still drains
        while q.take(timeout=0):
            pass
        assert q.taken == q.accepted == 3
        assert q.depth == 0
        assert q.put([4]) == 0           # closed queues refuse new work
    finally:
        svc.cancel_all()


def test_engine_fails_tasks_cleanly_when_pool_is_closed():
    with PilotSession() as s:
        p = s.add_pilot()
        b1 = s.submit_tasks([lambda: 1])
        assert b1.results(timeout=30) == [1]
        p.worker_pool.close()
        b2 = s.manager.engine.submit_tasks([lambda: 2])
        assert b2.wait(timeout=30)
        with pytest.raises(TaskError):
            b2[0].result()


# -- backpressure ---------------------------------------------------------
def test_dispatch_queue_backpressure_bound_is_honored():
    gate = threading.Event()
    peak = []

    with PilotSession() as s:
        p = s.add_pilot(task_workers=1, dispatch_queue_depth=8)
        pool = p.worker_pool
        blocker = s.submit_tasks([gate.wait])       # occupies the worker

        def producer():
            s.submit_tasks([lambda: None] * 64)     # must block at the bound

        t = threading.Thread(target=producer)
        t.start()
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and pool.queue.depth < 8:
            time.sleep(0.002)
        for _ in range(200):
            peak.append(pool.queue.depth)
            time.sleep(0.001)
        assert max(peak) <= 8                       # the bound is a bound
        gate.set()
        t.join(30)
        assert not t.is_alive()
        assert blocker.wait(timeout=30)
    assert max(peak) == 8                           # and it was reached


def test_dispatch_queue_put_timeout_returns_partial_count():
    q = DispatchQueue(bound=4, chunk=2)
    assert q.put([1, 2, 3, 4]) == 4
    assert q.put([5, 6], timeout=0.05) == 0         # full: timed out
    assert q.depth == 4
    assert q.put_force([5, 6]) == 2                 # re-bind path overshoots
    assert q.depth == 6
    got = []
    while q.depth:
        got.extend(q.take(timeout=1))
    assert got == [1, 2, 3, 4, 5, 6]                # FIFO, no loss, no dupes
    assert q.taken == q.accepted == 6


# -- batch scoring --------------------------------------------------------
def _du_on(tmp_path, name="sc", parts=4):
    backends = {"host": make_backend("host"),
                "device": make_backend("device")}
    arr = np.arange(parts * 8, dtype=np.float32).reshape(parts, 8)
    return DataUnit.from_array(name, arr, parts, backends, tier="host")


def test_score_batch_equals_n_single_scores(tmp_path):
    svc = PilotComputeService()
    try:
        pilots = [svc.submit_pilot(PilotComputeDescription(memory_gb=0.01))
                  for _ in range(2)]
        policy = LocalityPolicy()
        du = _du_on(tmp_path)
        descs = [ComputeUnitDescription(fn=lambda: None, input_data=(du,),
                                        affinity="a" if i % 2 else "")
                 for i in range(16)]
        for p in pilots:
            singles = [policy.score(p, d) for d in descs]
            assert policy.score_batch(p, descs) == singles   # bit-for-bit
    finally:
        svc.cancel_all()


def test_select_batch_round_robins_equal_pilots():
    svc = PilotComputeService()
    try:
        pilots = [svc.submit_pilot(PilotComputeDescription())
                  for _ in range(3)]
        policy = LocalityPolicy()
        descs = [ComputeUnitDescription(fn=lambda: None)] * 30
        placed = policy.select_batch(pilots, descs)
        counts = {}
        for p, _ in placed:
            counts[p.id] = counts.get(p.id, 0) + 1
        # one scoring pass + incremental queue penalty spreads equal
        # pilots evenly instead of piling the whole batch on the first
        assert sorted(counts.values()) == [10, 10, 10]
    finally:
        svc.cancel_all()


def test_engine_batch_counts_in_manager_stats():
    with PilotSession() as s:
        s.add_pilots(2)
        before = s.manager.stats()["submitted"]
        s.submit_tasks([lambda: None] * 64).wait(timeout=30)
        st = s.manager.stats()
        assert st["submitted"] - before == 64
        assert sum(st["per_pilot"].values()) == st["submitted"]


# -- retry / re-bind ------------------------------------------------------
def test_retry_rebinds_flaky_task_and_exhausts_budget():
    fails = {"n": 0}
    lock = threading.Lock()

    def flaky_once():
        with lock:
            fails["n"] += 1
            if fails["n"] == 1:
                raise RuntimeError("transient")
        return "ok"

    with PilotSession() as s:
        s.add_pilots(2)
        assert s.submit_tasks([flaky_once],
                              retries=1).results(timeout=30) == ["ok"]

        def always():
            raise RuntimeError("permanent")

        batch = s.submit_tasks([always], retries=0)
        assert batch.wait(timeout=30)
        with pytest.raises(RuntimeError, match="permanent"):
            batch[0].result()


def test_retry_exclusion_resets_when_all_pilots_failed():
    """PR 4 semantics, task-batched: with ONE pilot and retries=3, a
    twice-flaky task must land back on the same pilot (exclusion reset)
    instead of stranding."""
    fails = {"n": 0}
    lock = threading.Lock()

    def flaky_twice():
        with lock:
            fails["n"] += 1
            if fails["n"] <= 2:
                raise RuntimeError(f"transient {fails['n']}")
        return fails["n"]

    with PilotSession() as s:
        s.add_pilot()
        assert s.submit_tasks([flaky_twice],
                              retries=3).results(timeout=30) == [3]


def test_rebound_task_lands_on_surviving_pilot():
    """A task raising on pilot A re-binds onto pilot B (A excluded)."""
    with PilotSession() as s:
        a, b = s.add_pilots(2)
        seen = []
        lock = threading.Lock()

        def tattle():
            pid = current_pilot().id
            with lock:
                seen.append(pid)
                if len(seen) == 1:
                    raise RuntimeError("first landing fails")
            return pid

        batch = s.submit_tasks([tattle], retries=2)
        assert batch.wait(timeout=30)
        final = batch[0].result()
        assert final == seen[-1]
        assert len(seen) >= 2
        assert seen[1] != seen[0]       # excluded the pilot that failed it
