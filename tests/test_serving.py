"""Serving-path semantics: rolling SWA cache, long multi-step decode,
MLA absorbed decode, continuous batching invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import build_model


def _greedy_decode(m, params, cache, tokens, start_pos, steps):
    toks = []
    pos = jnp.full((tokens.shape[0],), start_pos, jnp.int32)
    cur = tokens
    for _ in range(steps):
        logits, cache = m.decode(params, cache, cur, pos)
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(cur)
        pos = pos + 1
    return jnp.concatenate(toks, axis=1), cache


def test_rolling_window_cache_forgets_distant_tokens():
    """Mixtral-style SWA rolling cache: decoding far past the window, the
    prompt's first token must stop influencing the output."""
    cfg = reduced(get_config("mixtral_8x22b"), sliding_window=8, num_layers=2)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    t1 = jax.random.randint(jax.random.key(1), (1, 6), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    out = {}
    for name, toks in (("a", t1), ("b", t2)):
        _, cache = m.prefill(params, {"tokens": toks}, max_len=64)
        # decode 16 steps with FIXED inputs so divergence can only come
        # from the caches (which differ only at position 0)
        fixed = jnp.full((1, 1), 7, jnp.int32)
        logits_seq = []
        pos = jnp.full((1,), 6, jnp.int32)
        c = cache
        for _ in range(16):
            logits, c = m.decode(params, c, fixed, pos)
            logits_seq.append(logits)
            pos = pos + 1
        out[name] = jnp.stack(logits_seq)
    diff = np.asarray(jnp.max(jnp.abs(out["a"] - out["b"]), axis=(1, 2)))
    assert diff[0] > 0          # early steps see position 0 (inside window)
    assert diff[-1] < 1e-5      # beyond the window: fully forgotten


def test_multi_step_decode_matches_full_forward():
    """Greedy 8-step decode == teacher-forced full forward argmaxes."""
    cfg = reduced(get_config("yi_9b"))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab_size)
    logits, cache = m.prefill(params, {"tokens": prompt}, max_len=32)
    first = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen, _ = _greedy_decode(m, params, cache, first, 8, 7)
    seq = jnp.concatenate([prompt, first, gen], axis=1)
    full = m.train_forward(params, {"tokens": seq})["logits"]
    # teacher-forced next-token argmax at each generated position
    for t in range(7):
        pos = prompt.shape[1] + t
        expect = jnp.argmax(full[:, pos], -1)
        np.testing.assert_array_equal(np.asarray(gen[:, t]),
                                      np.asarray(expect))


def test_ssm_decode_long_state_stability():
    """Mamba decode for 64 steps: state stays finite (no blowup)."""
    cfg = reduced(get_config("falcon_mamba_7b"))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab_size)
    logits, cache = m.prefill(params, {"tokens": prompt}, max_len=16)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen, cache = _greedy_decode(m, params, cache, cur, 8, 64)
    ssm_state = cache["main"]["ssm"]["ssm"]
    assert bool(jnp.isfinite(ssm_state).all())
    # random-init selective SSMs drift (decay ~exp(-dt|A|) near 1); the
    # invariant is boundedness, not magnitude
    assert float(jnp.abs(ssm_state).max()) < 1e8


def test_decode_kernel_parity_with_jnp_path():
    """decode_kernel=True (Pallas flash-decoding, interpret mode) must match
    the pure-jnp decode path at the full-model level."""
    cfg = reduced(get_config("yi_9b"))
    m_jnp = build_model(cfg)
    m_ker = build_model(dataclasses.replace(cfg, decode_kernel=True))
    params = m_jnp.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    _, cache1 = m_jnp.prefill(params, {"tokens": prompt}, max_len=32)
    _, cache2 = m_ker.prefill(params, {"tokens": prompt}, max_len=32)
    tok = prompt[:, -1:]
    pos = jnp.full((2,), 8, jnp.int32)
    l1, _ = m_jnp.decode(params, cache1, tok, pos)
    l2, _ = m_ker.decode(params, cache2, tok, pos)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=5e-2,
                               rtol=5e-2)
