"""Serving-path semantics: rolling SWA cache, long multi-step decode,
MLA absorbed decode, continuous batching invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models.model import build_model


def _greedy_decode(m, params, cache, tokens, start_pos, steps):
    toks = []
    pos = jnp.full((tokens.shape[0],), start_pos, jnp.int32)
    cur = tokens
    for _ in range(steps):
        logits, cache = m.decode(params, cache, cur, pos)
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(cur)
        pos = pos + 1
    return jnp.concatenate(toks, axis=1), cache


def test_rolling_window_cache_forgets_distant_tokens():
    """Mixtral-style SWA rolling cache: decoding far past the window, the
    prompt's first token must stop influencing the output."""
    cfg = reduced(get_config("mixtral_8x22b"), sliding_window=8, num_layers=2)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    t1 = jax.random.randint(jax.random.key(1), (1, 6), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    out = {}
    for name, toks in (("a", t1), ("b", t2)):
        _, cache = m.prefill(params, {"tokens": toks}, max_len=64)
        # decode 16 steps with FIXED inputs so divergence can only come
        # from the caches (which differ only at position 0)
        fixed = jnp.full((1, 1), 7, jnp.int32)
        logits_seq = []
        pos = jnp.full((1,), 6, jnp.int32)
        c = cache
        for _ in range(16):
            logits, c = m.decode(params, c, fixed, pos)
            logits_seq.append(logits)
            pos = pos + 1
        out[name] = jnp.stack(logits_seq)
    diff = np.asarray(jnp.max(jnp.abs(out["a"] - out["b"]), axis=(1, 2)))
    assert diff[0] > 0          # early steps see position 0 (inside window)
    assert diff[-1] < 1e-5      # beyond the window: fully forgotten


def test_multi_step_decode_matches_full_forward():
    """Greedy 8-step decode == teacher-forced full forward argmaxes."""
    cfg = reduced(get_config("yi_9b"))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab_size)
    logits, cache = m.prefill(params, {"tokens": prompt}, max_len=32)
    first = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen, _ = _greedy_decode(m, params, cache, first, 8, 7)
    seq = jnp.concatenate([prompt, first, gen], axis=1)
    full = m.train_forward(params, {"tokens": seq})["logits"]
    # teacher-forced next-token argmax at each generated position
    for t in range(7):
        pos = prompt.shape[1] + t
        expect = jnp.argmax(full[:, pos], -1)
        np.testing.assert_array_equal(np.asarray(gen[:, t]),
                                      np.asarray(expect))


def test_ssm_decode_long_state_stability():
    """Mamba decode for 64 steps: state stays finite (no blowup)."""
    cfg = reduced(get_config("falcon_mamba_7b"))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab_size)
    logits, cache = m.prefill(params, {"tokens": prompt}, max_len=16)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen, cache = _greedy_decode(m, params, cache, cur, 8, 64)
    ssm_state = cache["main"]["ssm"]["ssm"]
    assert bool(jnp.isfinite(ssm_state).all())
    # random-init selective SSMs drift (decay ~exp(-dt|A|) near 1); the
    # invariant is boundedness, not magnitude
    assert float(jnp.abs(ssm_state).max()) < 1e8


def test_decode_kernel_parity_with_jnp_path():
    """decode_kernel=True (Pallas flash-decoding, interpret mode) must match
    the pure-jnp decode path at the full-model level."""
    cfg = reduced(get_config("yi_9b"))
    m_jnp = build_model(cfg)
    m_ker = build_model(dataclasses.replace(cfg, decode_kernel=True))
    params = m_jnp.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    _, cache1 = m_jnp.prefill(params, {"tokens": prompt}, max_len=32)
    _, cache2 = m_ker.prefill(params, {"tokens": prompt}, max_len=32)
    tok = prompt[:, -1:]
    pos = jnp.full((2,), 8, jnp.int32)
    l1, _ = m_jnp.decode(params, cache1, tok, pos)
    l2, _ = m_ker.decode(params, cache2, tok, pos)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=5e-2,
                               rtol=5e-2)


# ---------------------------------------------------------------------------
# ServingEngine on the pilot substrate (PR 9).  A deterministic stub model
# (next token = last token + 1 mod vocab) makes every assertion exact —
# no float tolerance anywhere, so the refill/masking/recovery plumbing is
# tested in isolation from model numerics.
# ---------------------------------------------------------------------------
import tempfile
import time
from types import SimpleNamespace

from repro.core import PilotSession
from repro.core.pilot import State
from repro.serving import ServingEngine


class _StubModel:
    """next = (last + 1) % vocab; cache is a dict with batch axis 0."""

    def __init__(self, vocab=32, delay=0.0):
        self.cfg = SimpleNamespace(name="stub", vocab_size=vocab,
                                   vision_tokens=0, encoder_layers=0)
        self.vocab = vocab
        self.delay = delay

    def init(self, key):
        return {"w": jnp.zeros((4,), jnp.float32)}

    def _step(self, last):
        logits = jax.nn.one_hot((last + 1) % self.vocab, self.vocab) * 100.0
        return logits, {"last": last.astype(jnp.int32).reshape(-1, 1)}

    def _sleep(self):
        time.sleep(self.delay)
        return np.int32(0)

    def prefill(self, params, batch, max_len):
        return self._step(batch["tokens"][:, -1])

    def decode(self, params, cache, tokens, positions):
        tok = tokens[:, 0]
        if self.delay:
            # the engine jits decode; a bare time.sleep would run only at
            # trace time — io_callback makes the delay a runtime effect
            pause = jax.experimental.io_callback(
                self._sleep, jax.ShapeDtypeStruct((), jnp.int32),
                ordered=True)
            tok = tok + pause
        return self._step(tok)


def _expected(prompt, gen, vocab=32):
    return [(int(prompt[-1]) + 1 + i) % vocab for i in range(gen)]


def test_engine_refill_exact_token_counts():
    """More requests than batch rows: freed rows MUST be refilled from the
    queue (the old serve.py never drained pending after the first wave),
    and every request's output must be exact — so a row that serves
    request A then request B can't leak tokens across the splice."""
    model = _StubModel()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 32, size=4 + (i % 3)).astype(np.int32)
               for i in range(6)]
    with PilotSession() as s:
        s.add_pilots(1, memory_gb=0.25)
        with ServingEngine(s, model, batch_size=2, max_len=32,
                           page_tokens=4) as eng:
            eng.deploy()
            reqs = [eng.submit(p, 5) for p in prompts]
            eng.drain(timeout=60)
            for p, r in zip(prompts, reqs):
                assert r.result(timeout=5) == _expected(p, 5)
            st = eng.stats()
    assert st["completed"] == 6
    assert st["refills"] >= 4          # 6 requests through 2 rows
    assert st["tokens_served"] == 6 * 5  # exact: no padded/retired counting


def test_engine_inactive_rows_do_not_count_tokens():
    """Rows that finished early (short gen) or were padding in a prefill
    wave must stop sampling AND stop counting: tokens_served is exactly
    the sum of requested gen lengths (the old loop kept counting retired
    rows via the `generated[row] = -1e6` hack)."""
    model = _StubModel()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 32, size=4).astype(np.int32)
               for _ in range(3)]
    gens = [2, 9, 5]                   # ragged: rows retire at different steps
    with PilotSession() as s:
        s.add_pilots(1, memory_gb=0.25)
        with ServingEngine(s, model, batch_size=4, max_len=32,
                           page_tokens=4) as eng:   # batch 4 > 3 requests
            eng.deploy()
            reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
            eng.drain(timeout=60)
            for p, g, r in zip(prompts, gens, reqs):
                got = r.result(timeout=5)
                assert got == _expected(p, g)
                assert len(got) == g   # exactly g — not max(gens), not 0
            st = eng.stats()
    assert st["tokens_served"] == sum(gens)


def test_engine_recovers_requests_after_pilot_kill():
    """Kill a pilot mid-decode (state FAILED + volatile tiers lost, as the
    chaos harness does): its in-flight requests must be recovered from
    the durable KV-page partitions and finish on the surviving replica
    with byte-exact outputs and exact token accounting."""
    model = _StubModel(delay=0.02)     # slow decode so the kill lands mid-run
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 32, size=5).astype(np.int32)
               for _ in range(4)]
    with tempfile.TemporaryDirectory() as ckpt:
        with PilotSession(checkpoint_dir=ckpt, supervise=True) as s:
            pilots = s.add_pilots(2, memory_gb=0.25)
            with ServingEngine(s, model, batch_size=2, max_len=64,
                               page_tokens=4) as eng:
                eng.deploy()
                reqs = [eng.submit(p, 30) for p in prompts]
                time.sleep(0.25)       # let decode get going on both pilots
                # kill a pilot that actually owns in-flight requests, so
                # the recovery path is exercised regardless of routing
                victim = next((rep.pilot for rep in eng._replicas.values()
                               if rep.active), pilots[0])
                victim.state = State.FAILED
                if victim.tier_manager is not None:
                    victim.tier_manager.lose_volatile()
                eng.drain(timeout=120)
                for p, r in zip(prompts, reqs):
                    assert r.result(timeout=10) == _expected(p, 30)
                st = eng.stats()
    assert st["completed"] == 4        # zero data loss
    assert st["recovered_requests"] >= 1
    assert st["replica_deaths"] >= 1
