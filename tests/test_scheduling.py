"""Pluggable scheduling (repro.core.scheduling): LocalityPolicy parity
with the historical W_* constants (exact arithmetic), custom weights,
select() threading the winning score, the InterconnectModel cost math,
and cost-modelled cross-pilot replica reads (sibling fetch chosen iff
the modelled link beats the home re-pull)."""
import numpy as np
import pytest

from repro.core import (ComputeDataManager, ComputeUnitDescription, DataUnit,
                        InterconnectModel, Link, LocalityPolicy,
                        LocalityWeights, PilotComputeDescription,
                        PilotComputeService, PilotDataService, TierManager,
                        make_backend)
from repro.core.manager import (W_AFFINITY, W_CKPT, W_DEVICE, W_HOST,
                                W_LOCAL, W_QUEUE)


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


def _managed_du(name, device_budget, parts=4):
    tm = TierManager({"host": make_backend("host"),
                      "device": make_backend("device")},
                     {"device": device_budget}, promote_threshold=0)
    arr = np.ones((parts * 256, 4), np.float32)
    return DataUnit.from_array(name, arr, parts, tm.backends, tier="device",
                               tier_manager=tm)


def _home_du(name, parts=4, rows=64):
    arr = np.arange(parts * rows * 4, dtype=np.float32).reshape(-1, 4)
    return DataUnit.from_array(name, arr, parts,
                               {"host": make_backend("host")}, tier="host")


def _pds_pilot(svc, pds, device_budget=None):
    pilot = svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
    pilot.attach_tier_manager(TierManager(
        {"host": make_backend("host"), "device": make_backend("device")},
        {"device": device_budget}, promote_threshold=0))
    pds.register_pilot(pilot)
    return pilot


# -- LocalityPolicy parity ----------------------------------------------
def test_locality_policy_matches_legacy_constants_exactly(service):
    """The extracted policy must reproduce the historical W_* scoring
    bit-for-bit: every term hand-computed from the published formula."""
    pilot = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    policy = LocalityPolicy()
    part_bytes = 256 * 4 * 4

    # fully device-resident unmanaged-hierarchy DU: W_DEVICE * 1.0
    du_dev = _managed_du("full", device_budget=None)
    s = policy.score(pilot, ComputeUnitDescription(fn=lambda: 0,
                                                   input_data=(du_dev,)))
    assert s == W_DEVICE * 1.0 - W_QUEUE * pilot.utilization

    # half-demoted DU: W_DEVICE * 2/4 + W_HOST * 2/4
    du_half = _managed_du("half", device_budget=2 * part_bytes)
    assert du_half.resident_fraction("device") == 0.5
    s = policy.score(pilot, ComputeUnitDescription(fn=lambda: 0,
                                                   input_data=(du_half,)))
    assert s == W_DEVICE * 0.5 + W_HOST * 0.5 - W_QUEUE * pilot.utilization

    # all-host DU + matching affinity label
    du_host = _home_du("hosted")
    s = policy.score(pilot, ComputeUnitDescription(
        fn=lambda: 0, input_data=(du_host,), affinity="x"))
    assert s == W_HOST * 1.0 - W_QUEUE * pilot.utilization  # label mismatch
    pilot_aff = service.submit_pilot(PilotComputeDescription(
        backend="inprocess", affinity="x"))
    s = policy.score(pilot_aff, ComputeUnitDescription(
        fn=lambda: 0, input_data=(du_host,), affinity="x"))
    assert s == W_HOST * 1.0 + W_AFFINITY - W_QUEUE * pilot_aff.utilization


def test_locality_policy_replica_terms_match_legacy(service):
    """Per-pilot replica scoring: device/host/checkpoint/any-tier terms
    hand-computed against the registry residency."""
    pds = PilotDataService()
    a = _pds_pilot(service, pds)
    b = _pds_pilot(service, pds)
    du = pds.register(_home_du("rep", parts=4))
    du.replicate_to_pilot(a, parts=[0, 1, 2])       # 3 on-device replicas
    du.replicate_to_pilot(b, parts=[3], tier="host")
    policy = LocalityPolicy()
    desc = ComputeUnitDescription(fn=lambda: 0, input_data=(du,))
    sa, sb = policy.score(a, desc), policy.score(b, desc)
    assert sa == (W_DEVICE * 3 / 4 + W_LOCAL * 3 / 4
                  - W_QUEUE * a.utilization)
    assert sb == (W_HOST * 1 / 4 + W_LOCAL * 1 / 4
                  - W_QUEUE * b.utilization)
    # and the manager's default policy scores identically
    manager = ComputeDataManager(service)
    assert manager.score(a, desc) == sa
    assert manager.score(b, desc) == sb
    pds.close()


def test_custom_weights_change_placement(service):
    """Non-default weights are honored — the whole point of the strategy
    extraction (host-heavy weights flip the ranking)."""
    pds = PilotDataService()
    a = _pds_pilot(service, pds)
    b = _pds_pilot(service, pds)
    du = pds.register(_home_du("w", parts=4))
    du.replicate_to_pilot(a, parts=[0])                    # 1 device part
    du.replicate_to_pilot(b, parts=[1, 2, 3], tier="host")  # 3 host parts
    desc = ComputeUnitDescription(fn=lambda: 0, input_data=(du,))
    default = LocalityPolicy()
    assert default.score(a, desc) > default.score(b, desc)
    host_heavy = LocalityPolicy(LocalityWeights(device=1.0, host=100.0))
    assert host_heavy.score(b, desc) > host_heavy.score(a, desc)
    pds.close()


def test_select_returns_first_max_and_score(service):
    ps = [service.submit_pilot(PilotComputeDescription(backend="inprocess"))
          for _ in range(3)]
    policy = LocalityPolicy()
    desc = ComputeUnitDescription(fn=lambda: 0)
    best, score = policy.select(ps, desc)
    assert best is ps[0]                # ties resolve to the first pilot
    assert score == policy.score(ps[0], desc)
    with pytest.raises(ValueError):
        policy.select([], desc)


def test_submit_scores_each_pilot_exactly_once(service):
    """The old submit path re-scored the winner for `history` right after
    select_pilot's max() had already scored it — the winning score must be
    threaded through instead (hot-path cost ~ pilots x DUs x parts)."""

    class CountingPolicy(LocalityPolicy):
        def __init__(self):
            super().__init__()
            self.calls = 0

        def score(self, pilot, cu_desc):
            self.calls += 1
            return super().score(pilot, cu_desc)

    for _ in range(3):
        service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    policy = CountingPolicy()
    manager = ComputeDataManager(service, policy=policy)
    cu = manager.submit(ComputeUnitDescription(fn=lambda: "ok"))
    assert cu.result(30) == "ok"
    assert policy.calls == 3            # once per pilot, zero recomputes
    assert manager.history[-1]["score"] == max(
        LocalityPolicy().score(p, ComputeUnitDescription(fn=lambda: "ok"))
        for p in service.healthy_pilots())


# -- InterconnectModel ---------------------------------------------------
def test_link_cost_math_and_validation():
    link = Link(gbps=1.0, latency_s=0.5)
    assert link.cost(10 ** 9) == pytest.approx(1.5)   # 1 GB at 1 GB/s + lat
    assert Link(gbps=0.0).cost(1) == float("inf")
    with pytest.raises(ValueError):
        Link(gbps=-1.0)


def test_interconnect_links_and_home():
    ic = InterconnectModel(default=Link(gbps=10.0),
                           home=Link(gbps=1.0, latency_s=0.1))
    ic.set_link("a", "b", gbps=100.0, latency_s=0.0)
    nb = 10 ** 9
    assert ic.transfer_cost("a", "b", nb) == pytest.approx(nb / 100e9)
    assert ic.transfer_cost("b", "a", nb) == pytest.approx(nb / 100e9)
    assert ic.transfer_cost("a", "c", nb) == pytest.approx(nb / 10e9)
    assert ic.transfer_cost("a", "a", nb) == 0.0
    assert ic.home_cost(nb) == pytest.approx(0.1 + 1.0)


def test_sibling_fetch_chosen_iff_link_beats_home(service):
    """The ROADMAP item: a CU's pull into pilot B reads from sibling A's
    replica exactly when the modelled link cost beats a home re-pull."""
    # fast fabric, slow home: the sibling must serve the pull
    fast_fabric = InterconnectModel(default=Link(gbps=100.0),
                                    home=Link(gbps=0.001, latency_s=0.05))
    pds = PilotDataService(interconnect=fast_fabric)
    a, b = _pds_pilot(service, pds), _pds_pilot(service, pds)
    du = pds.register(_home_du("fab", parts=2))
    du.replicate_to_pilot(a, parts=[0])
    ref = np.asarray(du.partition(0)).copy()
    np.testing.assert_array_equal(du.partition(0, pilot=b), ref)
    assert pds.counters["sibling_reads"] == 1
    assert pds.counters["home_reads"] == 0
    assert any(e["op"] == "sibling-read" and e["src"] == a.id
               and e["dst"] == b.id for e in pds.events)
    pds.close()

    # slow fabric, fast home: the home re-pull must win
    slow_fabric = InterconnectModel(default=Link(gbps=0.0001, latency_s=0.5),
                                    home=Link(gbps=100.0))
    pds2 = PilotDataService(interconnect=slow_fabric)
    c, d = _pds_pilot(service, pds2), _pds_pilot(service, pds2)
    du2 = pds2.register(_home_du("slo", parts=2))
    du2.replicate_to_pilot(c, parts=[0])
    np.testing.assert_array_equal(du2.partition(0, pilot=d),
                                  np.asarray(du2.partition(0)))
    assert pds2.counters["home_reads"] >= 1
    assert pds2.counters["sibling_reads"] == 0
    pds2.close()


def test_sibling_fetch_recovers_when_home_is_gone(service):
    """Cost order never breaks the fallback chain: with the home copy
    deleted out from under the registry, a 'cheap home' model still ends
    up serving from the sibling replica."""
    ic = InterconnectModel(default=Link(gbps=0.001, latency_s=0.5),
                           home=Link(gbps=100.0))
    pds = PilotDataService(interconnect=ic)
    a, b = _pds_pilot(service, pds), _pds_pilot(service, pds)
    du = pds.register(_home_du("gone", parts=1))
    ref = np.asarray(du.partition(0)).copy()
    du.replicate_to_pilot(a, parts=[0])
    # rip out the home copy directly (not du.delete(): that would
    # coherently invalidate the replicas too)
    du.backends["host"].delete(du._key(0))
    np.testing.assert_array_equal(du.partition(0, pilot=b), ref)
    assert pds.counters["sibling_reads"] == 1
    pds.close()


def test_policy_sibling_credit_requires_interconnect(service):
    """A pilot holding nothing earns sibling credit only when a policy
    carries an interconnect whose link beats home — and never more than a
    pilot actually holding the bytes."""
    pds = PilotDataService()
    a, b = _pds_pilot(service, pds), _pds_pilot(service, pds)
    du = pds.register(_home_du("cred", parts=4))
    du.replicate_to_pilot(a)            # a holds everything, b nothing
    desc = ComputeUnitDescription(fn=lambda: 0, input_data=(du,))
    plain = LocalityPolicy()
    fabric = LocalityPolicy(interconnect=InterconnectModel(
        default=Link(gbps=100.0), home=Link(gbps=0.001, latency_s=0.05)))
    assert plain.score(b, desc) == 0.0 - W_QUEUE * b.utilization
    assert fabric.score(b, desc) > plain.score(b, desc)   # credit exists
    assert fabric.score(a, desc) > fabric.score(b, desc)  # holder still wins
    # credit covers only MISSING partitions: a pilot holding everything
    # earns pure residency (identical with and without the interconnect)
    assert fabric.score(a, desc) == plain.score(a, desc)
    # a partial holder is credited for the unheld remainder only, never
    # more than one sibling weight per missing partition
    du.replicate_to_pilot(b, parts=[0])
    gap = fabric.score(b, desc) - plain.score(b, desc)
    from repro.core.scheduling import W_SIBLING
    assert 0.0 < gap <= W_SIBLING * 3 / 4 + 1e-9
    pds.close()
