"""AxisRules / resolve_pspec invariants (hypothesis property tests)."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
from repro.launch.mesh import make_abstract_mesh
from repro.parallel.sharding import AxisRules, resolve_pspec

SRC = Path(__file__).resolve().parents[1] / "src"


def _fake_mesh(shape, axes):
    """Mesh over abstract devices (no allocation) for spec resolution."""
    return make_abstract_mesh(shape, axes)


LOGICALS = ["batch", "seq", "embed", "heads", "kv_heads", "mlp", "vocab",
            "expert", "layers", None]


@settings(max_examples=200, deadline=None)
@given(dims=st.lists(st.sampled_from(LOGICALS), min_size=1, max_size=4),
       sizes=st.lists(st.sampled_from([1, 2, 3, 4, 8, 16, 25, 36, 48, 129]),
                      min_size=1, max_size=4))
def test_resolve_pspec_invariants(dims, sizes):
    n = min(len(dims), len(sizes))
    dims, sizes = dims[:n], sizes[:n]
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    rules = AxisRules()
    spec = resolve_pspec(dims, sizes, mesh, rules)
    axis_sizes = dict(zip(("pod", "data", "model"), (2, 16, 16)))
    used = []
    for entry, size in zip(tuple(spec) + (None,) * n, sizes):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        prod = int(np.prod([axis_sizes[a] for a in axes]))
        # 1. divisibility always holds
        assert size % prod == 0, (dims, sizes, spec)
        used.extend(axes)
    # 2. no mesh axis used twice
    assert len(used) == len(set(used)), (dims, sizes, spec)


def _entry(spec, i):
    return spec[i] if i < len(spec) else None


def test_kv_heads_fall_back_to_replication():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    spec = resolve_pspec(("embed", "kv_heads", "head_dim"), (4096, 8, 128),
                         mesh, AxisRules())
    assert _entry(spec, 1) is None  # 8 kv heads % 16 -> replicate
    assert _entry(spec, 0) == "data"


def test_expert_axis_conflict_resolution():
    """Mixtral: 8 experts can't take the 16-way model axis; the expert_mlp
    dim picks it up instead."""
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = AxisRules()
    spec = resolve_pspec(("expert", "expert_embed", "expert_mlp"),
                         (8, 6144, 16384), mesh, rules)
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")
    # DeepSeek: 256 experts take the model axis; mlp falls back to None
    spec2 = resolve_pspec(("expert", "expert_embed", "expert_mlp"),
                          (256, 7168, 2048), mesh, rules)
    assert _entry(spec2, 0) == "model"
    assert _entry(spec2, 1) == "data"


def test_rule_override_priority():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    rules = AxisRules().override(("seq", "model"))
    spec = resolve_pspec(("batch", "seq"), (256, 4096), mesh, rules)
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_batch_one_replicates():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    spec = resolve_pspec(("batch", "long_seq"), (1, 524288), mesh, AxisRules())
    assert _entry(spec, 0) is None
    assert _entry(spec, 1) == ("data", "model")
