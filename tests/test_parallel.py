"""Multi-device (placeholder grid) tests: pipeline parallelism, compressed
pod reduction, dry-run lowering. Run in subprocesses because the device
count must be fixed before jax initializes."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parents[1] / "src"


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_parallel_matches_sequential():
    _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_forward, bubble_fraction
        mesh = make_mesh((4,), ("pipe",))
        P, M, mb, d = 4, 8, 2, 16
        ws = jax.random.normal(jax.random.key(0), (P, d, d)) * 0.1
        xs = jax.random.normal(jax.random.key(1), (M, mb, d))
        layer_fn = lambda w, x: jnp.tanh(x @ w)
        with mesh:
            out = pipeline_forward(layer_fn, ws, xs, mesh, axis="pipe")
        ref = xs
        for i in range(P):
            ref = jnp.tanh(ref @ ws[i])
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
        assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
        print("OK")
    """)


def test_compressed_pod_mean_quantization_bound():
    _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.optim.compression import compressed_pod_mean, init_residuals
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jax.random.normal(jax.random.key(0), (8, 16), jnp.float32)}
        r = init_residuals(g)
        with mesh:
            gm, rn = jax.jit(lambda g, r: compressed_pod_mean(g, r, mesh))(g, r)
        rel = float(jnp.max(jnp.abs(gm["w"] - g["w"]))) / float(jnp.max(jnp.abs(g["w"])))
        assert rel < 0.02, rel
        # error feedback: residual equals quantization error
        assert float(jnp.linalg.norm(rn["w"])) > 0
        print("OK")
    """)


def test_dryrun_cell_small_mesh():
    """Lower + compile one real cell on a 4x2 grid (fast sanity of the
    dry-run machinery without the 512-device cost)."""
    _run("""
        import jax
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig, SHAPES, reduced
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import build_lowerable
        from repro.parallel.sharding import AxisRules
        import dataclasses
        cfg = reduced(get_config("llama3_2_1b"), num_layers=2)
        mesh = make_mesh((4, 2), ("data", "model"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
        jitted, args = build_lowerable(cfg, shape, mesh, AxisRules(), ParallelConfig())
        with mesh:
            compiled = jitted.lower(*args).compile()
        assert compiled.memory_analysis() is not None
        print("OK")
    """)


def test_dryrun_decode_cell_small_mesh():
    _run("""
        import jax, dataclasses
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig, SHAPES, reduced
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import build_lowerable
        from repro.parallel.sharding import AxisRules
        cfg = reduced(get_config("yi_9b"), num_layers=2)
        mesh = make_mesh((4, 2), ("data", "model"))
        shape = dataclasses.replace(SHAPES["decode_32k"], seq_len=128, global_batch=8)
        jitted, args = build_lowerable(cfg, shape, mesh, AxisRules(), ParallelConfig())
        with mesh:
            compiled = jitted.lower(*args).compile()
        print("OK")
    """)


def test_elastic_shrink_then_lower():
    """Form a mesh, 'lose' devices, re-form smaller, relower the step."""
    _run("""
        import jax, dataclasses
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig, SHAPES, reduced
        from repro.launch.dryrun import build_lowerable
        from repro.parallel.sharding import AxisRules
        from repro.runtime.elastic import ElasticController
        cfg = reduced(get_config("llama3_2_1b"), num_layers=2)
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
        ctl = ElasticController(model_parallel=2)
        mesh = ctl.form(jax.devices())                      # 4x2
        jitted, args = build_lowerable(cfg, shape, mesh, AxisRules(), ParallelConfig())
        with mesh:
            jitted.lower(*args).compile()
        mesh2 = ctl.on_failure(jax.devices()[:4])           # 2x2 survivors
        jitted2, args2 = build_lowerable(cfg, shape, mesh2, AxisRules(), ParallelConfig())
        with mesh2:
            jitted2.lower(*args2).compile()
        assert ctl.generation == 2
        print("OK")
    """)
